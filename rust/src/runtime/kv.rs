//! Host-side KV cache state: per-layer contiguous slot arrays + occupancy +
//! original-token-position bookkeeping.
//!
//! Layout matches the device tensors exactly: `k`/`v` are row-major
//! `[L, H, C, Dh]` f32. Slot order within a layer is time order; eviction is
//! an order-preserving per-layer gather (`retain_slots`), after which slot
//! index == cache-relative RoPE position on the device side.

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct KvCache {
    pub l: usize,
    pub h: usize,
    pub c: usize,
    pub dh: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Valid slot count per layer.
    pub lens: Vec<usize>,
    /// Original token index of each valid slot, per layer (time-ordered).
    pub positions: Vec<Vec<u64>>,
    /// Accumulated attention mass per valid slot, per layer (H2O-family
    /// bookkeeping; stays zero on the fast path).
    pub mass: Vec<Vec<f64>>,
}

impl KvCache {
    pub fn new(l: usize, h: usize, c: usize, dh: usize) -> Self {
        Self {
            l,
            h,
            c,
            dh,
            k: vec![0.0; l * h * c * dh],
            v: vec![0.0; l * h * c * dh],
            lens: vec![0; l],
            positions: vec![Vec::new(); l],
            mass: vec![Vec::new(); l],
        }
    }

    pub fn lens_i32(&self) -> Vec<i32> {
        self.lens.iter().map(|&x| x as i32).collect()
    }

    /// Total bytes resident for valid slots (the OOM-accounting metric).
    pub fn kv_bytes(&self) -> usize {
        self.lens.iter().map(|&n| 2 * self.h * n * self.dh * 4).sum()
    }

    /// Max occupancy across layers.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    #[inline]
    fn row_offset(&self, l: usize, h: usize, slot: usize) -> usize {
        ((l * self.h + h) * self.c + slot) * self.dh
    }

    /// Append one layer's window K/V rows (from a score program's output,
    /// shaped `[H, W, Dh]` with `n_valid <= W` rows valid) at the tail.
    pub fn append_layer(
        &mut self,
        layer: usize,
        win_k: &[f32],
        win_v: &[f32],
        w: usize,
        n_valid: usize,
        first_pos: u64,
    ) -> Result<()> {
        let len = self.lens[layer];
        if len + n_valid > self.c {
            bail!("cache overflow: layer {layer} len {len} + {n_valid} > C {}", self.c);
        }
        debug_assert_eq!(win_k.len(), self.h * w * self.dh);
        for hh in 0..self.h {
            for i in 0..n_valid {
                let src = (hh * w + i) * self.dh;
                let dst = self.row_offset(layer, hh, len + i);
                self.k[dst..dst + self.dh].copy_from_slice(&win_k[src..src + self.dh]);
                self.v[dst..dst + self.dh].copy_from_slice(&win_v[src..src + self.dh]);
            }
        }
        self.lens[layer] = len + n_valid;
        for i in 0..n_valid {
            self.positions[layer].push(first_pos + i as u64);
            self.mass[layer].push(0.0);
        }
        Ok(())
    }

    /// Order-preserving gather: keep exactly the slots in `keep` (sorted,
    /// unique, all < lens[layer]) for one layer.
    pub fn retain_slots(&mut self, layer: usize, keep: &[usize]) -> Result<()> {
        let len = self.lens[layer];
        let mut prev: Option<usize> = None;
        for &s in keep {
            if s >= len {
                bail!("retain_slots: slot {s} >= len {len}");
            }
            if let Some(p) = prev {
                if s <= p {
                    bail!("retain_slots: indices must be strictly increasing");
                }
            }
            prev = Some(s);
        }
        for hh in 0..self.h {
            for (dst_i, &src_i) in keep.iter().enumerate() {
                if dst_i == src_i {
                    continue; // prefix already in place
                }
                let src = self.row_offset(layer, hh, src_i);
                let dst = self.row_offset(layer, hh, dst_i);
                self.k.copy_within(src..src + self.dh, dst);
                self.v.copy_within(src..src + self.dh, dst);
            }
        }
        self.positions[layer] = keep.iter().map(|&s| self.positions[layer][s]).collect();
        self.mass[layer] = keep.iter().map(|&s| self.mass[layer][s]).collect();
        self.lens[layer] = keep.len();
        Ok(())
    }

    /// Replace full device-shaped state (from a generate program's outputs).
    pub fn replace_from_device(&mut self, k: Vec<f32>, v: Vec<f32>, lens: &[i32], appended: usize) {
        debug_assert_eq!(k.len(), self.k.len());
        self.k = k;
        self.v = v;
        for l in 0..self.l {
            let new_len = lens[l] as usize;
            let old_len = self.lens[l];
            debug_assert_eq!(new_len, old_len + appended);
            let next_pos = self.positions[l].last().map(|&p| p + 1).unwrap_or(0);
            for i in 0..new_len - old_len {
                self.positions[l].push(next_pos + i as u64);
                self.mass[l].push(0.0);
            }
            self.lens[l] = new_len;
        }
    }

    /// Add per-slot attention mass from a scored program (`mass_row` is the
    /// device `[C+W]` or `[C]` row for `layer`; only the first lens entries
    /// apply to resident slots).
    pub fn add_mass(&mut self, layer: usize, mass_row: &[f32]) {
        let n = self.lens[layer].min(mass_row.len());
        for i in 0..n {
            self.mass[layer][i] += mass_row[i] as f64;
        }
    }

    /// Consistency invariants (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<()> {
        for l in 0..self.l {
            if self.lens[l] > self.c {
                bail!("len > capacity");
            }
            if self.positions[l].len() != self.lens[l] || self.mass[l].len() != self.lens[l] {
                bail!("bookkeeping length mismatch");
            }
            for w in self.positions[l].windows(2) {
                if w[0] >= w[1] {
                    bail!("positions not strictly increasing in layer {l}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(l: usize, h: usize, c: usize, dh: usize, n: usize) -> KvCache {
        let mut kv = KvCache::new(l, h, c, dh);
        for layer in 0..l {
            let w = n;
            let mut wk = vec![0.0f32; h * w * dh];
            let mut wv = vec![0.0f32; h * w * dh];
            for hh in 0..h {
                for i in 0..w {
                    for d in 0..dh {
                        wk[(hh * w + i) * dh + d] = (layer * 1000 + hh * 100 + i) as f32;
                        wv[(hh * w + i) * dh + d] = -((layer * 1000 + hh * 100 + i) as f32);
                    }
                }
            }
            kv.append_layer(layer, &wk, &wv, w, n, 0).unwrap();
        }
        kv
    }

    #[test]
    fn append_and_invariants() {
        let kv = filled(2, 2, 16, 4, 5);
        assert_eq!(kv.lens, vec![5, 5]);
        kv.check_invariants().unwrap();
        assert_eq!(kv.kv_bytes(), 2 * 2 * 2 * 5 * 4 * 4);
    }

    #[test]
    fn append_overflow_fails() {
        let mut kv = KvCache::new(1, 1, 4, 2);
        let w = vec![0.0; 1 * 6 * 2];
        assert!(kv.append_layer(0, &w, &w, 6, 6, 0).is_err());
    }

    #[test]
    fn retain_gathers_rows() {
        let mut kv = filled(2, 2, 16, 4, 6);
        kv.retain_slots(0, &[0, 2, 5]).unwrap();
        assert_eq!(kv.lens[0], 3);
        assert_eq!(kv.positions[0], vec![0, 2, 5]);
        // head 1 row 1 should now hold original slot 2's value (=102)
        let off = ((0 * 2 + 1) * 16 + 1) * 4;
        assert_eq!(kv.k[off], 102.0);
        // layer 1 untouched
        assert_eq!(kv.lens[1], 6);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retain_rejects_bad_indices() {
        let mut kv = filled(1, 1, 8, 2, 4);
        assert!(kv.retain_slots(0, &[2, 1]).is_err());
        assert!(kv.retain_slots(0, &[0, 9]).is_err());
        assert!(kv.retain_slots(0, &[1, 1]).is_err());
    }

    #[test]
    fn mass_tracking() {
        let mut kv = filled(1, 1, 8, 2, 4);
        kv.add_mass(0, &[1.0, 2.0, 3.0, 4.0, 99.0]);
        assert_eq!(kv.mass[0], vec![1.0, 2.0, 3.0, 4.0]);
        kv.retain_slots(0, &[1, 3]).unwrap();
        assert_eq!(kv.mass[0], vec![2.0, 4.0]);
    }
}
