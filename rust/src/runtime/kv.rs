//! Host-side KV cache state over the shared paged arena: per-layer page
//! tables + occupancy + original-token-position bookkeeping + dirty-range
//! tracking against the last materialized dense image.
//!
//! Rows live in fixed-size arena pages ([`PAGE_SLOTS`] slots per page) stored
//! **head-major** `[H, PAGE_SLOTS, Dh]`: one head's slots are contiguous, so
//! gather/scatter against the device-contiguous `[L, H, C, Dh]` layout moves
//! whole `PAGE_SLOTS * Dh` runs instead of `Dh`-sized fragments. Slot order
//! within a layer is time order; eviction is an order-preserving in-place
//! remap ([`KvCache::retain_slots`]) that only touches rows whose slot index
//! changes, after which slot index == cache-relative RoPE position on the
//! device side.
//!
//! Every mutation (append, retain, truncate, device merge) records which slot
//! ranges diverged from the image materialized at the last
//! [`KvCache::mark_synced`] point, so the transfer layer
//! ([`super::transfer::ScratchPool`]) re-copies only those ranges into its
//! reusable scratch — a pure-append decode step gathers only the appended
//! rows, and an unchanged cache gathers nothing. See PERF.md for the
//! dirty-tracking invariants.
//!
//! Page-table entries are either privately **owned** (mutable in place) or
//! frozen **shared** pages ([`SharedPage`], refcounted): the cross-request
//! prefix cache freezes a donor's pages at prefill-chunk boundaries
//! ([`KvCache::freeze_pages`]) and a forked sequence adopts the same pages
//! ([`KvCache::adopt_shared`]) without copying. The first mutation that
//! would touch a shared page materializes a private copy first
//! (copy-on-write; a sole-reader page is reclaimed without copying). CoW is
//! content-preserving, so it needs no dirty marking of its own — the
//! triggering mutation marks its ranges exactly as on owned pages, and the
//! `(id, sync_gen)` stamps stay valid. See PERF.md "Prefix sharing".
//!
//! # Tiered compression
//!
//! With quantization enabled ([`KvCache::set_quant`], the serving
//! `--kv-quant cold-q8` default), cold pages demote to int8
//! ([`KvCache::demote_cold`]): pages whose every token is older than the
//! engine's cutoff are re-encoded as [`super::arena::QuantPage`]s (~4x
//! smaller), skipping the attention-sink page, the hot tail page, and any
//! page overlapping an open dirty range. A demotion changes stored values,
//! so it marks the page's slots dirty exactly once; gather paths dequantize
//! per-head runs transparently. **No quantized page is ever written in
//! place** — every mutation path re-materializes f32 first ([`owned_page`]
//! promotes on CoW un-share and on owned Q8 entries alike), and compaction
//! re-demotes pages that were cold before it ran, bounding the transient
//! f32 spike to the slots actually moved. See PERF.md "Tiered compression".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};
use std::time::Instant;

use anyhow::{bail, Result};

use super::arena::{KvArena, Page, PageData, Precision, SharedPage, PAGE_SLOTS};
use super::error::CallError;
use crate::obs::{self, EventKind};

/// Unique-per-instance cache ids: the scratch-pool key that makes a dense
/// image attributable to exactly one cache (clones and resets get fresh ids).
static NEXT_CACHE_ID: AtomicU64 = AtomicU64::new(1);

/// Byte counts from one gather: page→dense copies and stale-tail zero-fill.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatherBytes {
    /// Bytes copied from pages into the dense image (K + V).
    pub copied: u64,
    /// Bytes zero-filled where the cache shrank below the old image (K + V).
    pub zeroed: u64,
    /// Wall-clock nanoseconds spent dequantizing Q8 pages during the copy
    /// (zero when every touched page is f32).
    pub dequant_ns: u64,
}

impl GatherBytes {
    pub fn total(&self) -> u64 {
        self.copied + self.zeroed
    }
}

/// One page-table slot: a privately owned page (mutable in place once f32)
/// or a frozen shared page (copy-on-write on the first mutation). Either
/// variant may hold f32 or Q8 data — see [`PageData`].
enum PageEntry {
    Owned(PageData),
    Shared(SharedPage),
}

impl PageEntry {
    /// Read access, whichever variant.
    #[inline]
    fn page(&self) -> &PageData {
        match self {
            PageEntry::Owned(p) => p,
            PageEntry::Shared(s) => s.page(),
        }
    }

    fn is_shared(&self) -> bool {
        matches!(self, PageEntry::Shared(_))
    }

    /// Storage precision of the underlying page, whichever variant.
    fn precision(&self) -> Precision {
        self.page().precision()
    }

    /// Actual bytes held by the underlying page (precision-aware).
    fn bytes(&self, row_width: usize) -> usize {
        self.page().bytes(row_width)
    }

    /// Mutable access to an entry the caller has already made owned f32
    /// (via [`owned_page`]). Panics on a shared entry — that would be a
    /// missed CoW, i.e. silent corruption of every other reader — and on a
    /// quantized entry — no quantized page is ever written in place.
    fn owned_mut(&mut self) -> &mut Page {
        match self {
            PageEntry::Owned(p) => p.expect_f32_mut(),
            PageEntry::Shared(_) => panic!("mutation of a shared page without CoW"),
        }
    }

    /// Freeze in place: convert an owned page to shared (no byte movement,
    /// accounting unchanged) and hand out a handle; an already-shared page
    /// just clones one.
    fn freeze(&mut self, arena: &KvArena, row_width: usize) -> SharedPage {
        if let PageEntry::Shared(sp) = self {
            return sp.clone();
        }
        let placeholder = PageEntry::Owned(PageData::F32(Page { k: Vec::new(), v: Vec::new() }));
        let PageEntry::Owned(page) = std::mem::replace(self, placeholder) else {
            unreachable!("shared handled above");
        };
        let sp = SharedPage::freeze(arena.clone(), row_width, page);
        *self = PageEntry::Shared(sp.clone());
        sp
    }
}

/// Make `table[pi]` privately owned **f32** and return the mutable page —
/// the single choke point every mutation goes through. A shared entry whose
/// other readers all dropped is reclaimed in place (free); one that is
/// still shared is copied into a freshly allocated f32 page (copy-on-write,
/// counted in `ArenaStats::cow_copies`; a Q8 source dequantizes during the
/// copy). An owned Q8 entry is promoted: dequantized into a fresh f32 page,
/// the Q8 page freed. On allocation failure the entry is left untouched.
fn owned_page<'a>(
    arena: &KvArena,
    row_width: usize,
    cache_id: u64,
    table: &'a mut [PageEntry],
    pi: usize,
) -> Result<&'a mut Page> {
    if table[pi].is_shared() {
        let placeholder = PageEntry::Owned(PageData::F32(Page { k: Vec::new(), v: Vec::new() }));
        let PageEntry::Shared(shared) = std::mem::replace(&mut table[pi], placeholder) else {
            unreachable!("checked shared above");
        };
        let owned = match shared.try_unshare() {
            Ok(page) => page,
            Err(shared) => {
                let mut copy = match arena.alloc(row_width) {
                    Ok(copy) => copy,
                    Err(e) => {
                        table[pi] = PageEntry::Shared(shared);
                        return Err(e);
                    }
                };
                match shared.page() {
                    PageData::F32(p) => {
                        copy.k.copy_from_slice(&p.k);
                        copy.v.copy_from_slice(&p.v);
                    }
                    PageData::Q8(q) => {
                        q.decode_into(&mut copy);
                        obs::record(EventKind::QuantPromote, cache_id, 0, pi as i64, 1);
                    }
                }
                arena.note_cow();
                PageData::F32(copy)
            }
        };
        table[pi] = PageEntry::Owned(owned);
    }
    if table[pi].precision() == Precision::Q8 {
        // promote: a write follows, and quantized pages are never written
        // in place (alloc first so failure leaves the Q8 entry intact)
        let mut promoted = arena.alloc(row_width)?;
        let PageEntry::Owned(PageData::Q8(q)) = &table[pi] else {
            unreachable!("entry is owned (un-shared above) and Q8 (checked)");
        };
        q.decode_into(&mut promoted);
        obs::record(EventKind::QuantPromote, cache_id, 0, pi as i64, 0);
        let old = std::mem::replace(&mut table[pi], PageEntry::Owned(PageData::F32(promoted)));
        let PageEntry::Owned(data) = old else {
            unreachable!("owned checked above");
        };
        arena.free(row_width, data);
    }
    Ok(table[pi].owned_mut())
}

pub struct KvCache {
    pub l: usize,
    pub h: usize,
    pub c: usize,
    pub dh: usize,
    arena: KvArena,
    /// Per-layer page table: page `i` backs slots
    /// `[i * PAGE_SLOTS, (i + 1) * PAGE_SLOTS)`. Entries are owned pages or
    /// frozen shared pages (CoW on first mutation).
    pages: Vec<Vec<PageEntry>>,
    /// Valid slot count per layer.
    pub lens: Vec<usize>,
    /// Original token index of each valid slot, per layer (time-ordered).
    pub positions: Vec<Vec<u64>>,
    /// Accumulated attention mass per valid slot, per layer (H2O-family
    /// bookkeeping; stays zero on the fast path).
    pub mass: Vec<Vec<f64>>,
    /// Unique instance id (scratch-pool key).
    id: u64,
    /// Bumped by [`Self::mark_synced`]; a scratch image is incremental-valid
    /// iff it recorded this exact (id, sync_gen) pair.
    sync_gen: u64,
    /// Per-layer slot interval `[lo, hi)` that diverged from the image at the
    /// last sync point (`None` = layer unchanged). A single merged interval:
    /// appends/evictions/truncations are all tail-heavy, so the union of the
    /// true dirty set stays tight in practice.
    dirty: Vec<Option<(usize, usize)>>,
    /// Cold-page quantization enabled (`--kv-quant cold-q8`). Off by
    /// default: every page stays f32 and [`Self::demote_cold`] is a no-op,
    /// keeping the exact-mode path byte-identical to pre-quantization
    /// behavior.
    quant: bool,
    /// High-water demotion cutoff: tokens at positions strictly below this
    /// are cold. Compaction uses it to re-demote pages that were Q8 before
    /// the move pass promoted them.
    quant_cutoff: u64,
    /// Liveness token: staging tiers (scratch pool, device tier) hold a
    /// [`Weak`] to it and drop their entries once the cache is gone — the
    /// same lifecycle as the Drop → arena page return path, extended to
    /// off-cache state keyed by `id`.
    alive: Arc<()>,
}

impl KvCache {
    /// Allocate against the process-wide arena (the serving default).
    pub fn new(l: usize, h: usize, c: usize, dh: usize) -> Self {
        Self::with_arena(KvArena::global().clone(), l, h, c, dh)
    }

    /// Allocate against a specific arena (isolated pools for tests/benches).
    pub fn with_arena(arena: KvArena, l: usize, h: usize, c: usize, dh: usize) -> Self {
        Self {
            l,
            h,
            c,
            dh,
            arena,
            pages: (0..l).map(|_| Vec::new()).collect(),
            lens: vec![0; l],
            positions: vec![Vec::new(); l],
            mass: vec![Vec::new(); l],
            id: NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed),
            sync_gen: 0,
            dirty: vec![None; l],
            quant: false,
            quant_cutoff: 0,
            alive: Arc::new(()),
        }
    }

    /// Enable/disable cold-page Q8 demotion for this cache (the engine sets
    /// this from `--kv-quant`). Existing pages keep their precision; only
    /// future [`Self::demote_cold`] / [`Self::freeze_pages`] calls quantize.
    pub fn set_quant(&mut self, on: bool) {
        self.quant = on;
    }

    /// Whether cold-page Q8 demotion is enabled.
    pub fn quant_enabled(&self) -> bool {
        self.quant
    }

    /// Floats per slot row (`H * Dh`) — the arena pooling key.
    #[inline]
    pub fn row_width(&self) -> usize {
        self.h * self.dh
    }

    /// Elements of one dense `[L, H, C, Dh]` image (K or V).
    #[inline]
    pub fn dense_elems(&self) -> usize {
        self.l * self.h * self.c * self.dh
    }

    /// Unique instance id (fresh per construction/clone/reset).
    #[inline]
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Sync-point generation; see [`Self::mark_synced`].
    #[inline]
    pub fn sync_gen(&self) -> u64 {
        self.sync_gen
    }

    /// Liveness handle for staging tiers: the returned [`Weak`] reports zero
    /// strong counts once this cache is dropped, letting the scratch pool
    /// and the device-residency tier release entries keyed by [`Self::id`]
    /// without a back-pointer from the cache to them.
    pub fn residency_token(&self) -> Weak<()> {
        Arc::downgrade(&self.alive)
    }

    /// True when no slot range diverged since the last sync point.
    pub fn is_clean(&self) -> bool {
        self.dirty.iter().all(|d| d.is_none())
    }

    /// Dirty slot interval for one layer (`None` = unchanged since sync).
    pub fn dirty_range(&self, layer: usize) -> Option<(usize, usize)> {
        self.dirty[layer]
    }

    /// Declare the current state fully materialized: clears dirty ranges and
    /// bumps the sync generation. Only the transfer layer should call this —
    /// immediately after it copied the dirty ranges (or a full image) into a
    /// scratch, or absorbed a device image that equals the current state.
    pub fn mark_synced(&mut self) {
        self.sync_gen += 1;
        for d in self.dirty.iter_mut() {
            *d = None;
        }
    }

    fn mark_dirty(&mut self, layer: usize, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        debug_assert!(hi <= self.c);
        self.dirty[layer] = Some(match self.dirty[layer] {
            None => (lo, hi),
            Some((a, b)) => (a.min(lo), b.max(hi)),
        });
    }

    pub fn lens_i32(&self) -> Vec<i32> {
        self.lens.iter().map(|&x| x as i32).collect()
    }

    /// Logical bytes for valid slots (the paper's OOM-accounting metric).
    pub fn kv_bytes(&self) -> usize {
        self.lens.iter().map(|&n| 2 * self.h * n * self.dh * 4).sum()
    }

    /// Actual bytes held in the arena (page-granular, mixed-precision
    /// occupancy — what the serving admission control sees; a demoted Q8
    /// page contributes ~1/4 of an f32 page).
    pub fn resident_bytes(&self) -> usize {
        let rw = self.row_width();
        self.pages.iter().flat_map(|t| t.iter()).map(|e| e.bytes(rw)).sum()
    }

    /// Pages of one layer currently held quantized (tests and diagnostics).
    pub fn n_quant_pages(&self, layer: usize) -> usize {
        self.pages[layer].iter().filter(|e| e.precision() == Precision::Q8).count()
    }

    /// Pages currently mapped for one layer.
    pub fn n_pages(&self, layer: usize) -> usize {
        self.pages[layer].len()
    }

    /// Max occupancy across layers.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Offset of (head, in-page slot) in the head-major page buffer.
    #[inline]
    fn page_off(&self, head: usize, slot_in_page: usize) -> usize {
        (head * PAGE_SLOTS + slot_in_page) * self.dh
    }

    /// One slot's K row for one head (`Dh` floats). Borrowed straight from
    /// the page, so only valid on f32 pages (tests/diagnostics; quantized
    /// slots are read through the dequantizing gather paths).
    pub fn row_k(&self, layer: usize, head: usize, slot: usize) -> &[f32] {
        let off = self.page_off(head, slot % PAGE_SLOTS);
        &self.pages[layer][slot / PAGE_SLOTS].page().expect_f32().k[off..off + self.dh]
    }

    /// One slot's V row for one head (`Dh` floats; f32 pages only, see
    /// [`Self::row_k`]).
    pub fn row_v(&self, layer: usize, head: usize, slot: usize) -> &[f32] {
        let off = self.page_off(head, slot % PAGE_SLOTS);
        &self.pages[layer][slot / PAGE_SLOTS].page().expect_f32().v[off..off + self.dh]
    }

    /// Pages of one layer currently held as frozen shared pages (tests and
    /// diagnostics; owned pages make up the rest of [`Self::n_pages`]).
    pub fn n_shared_pages(&self, layer: usize) -> usize {
        self.pages[layer].iter().filter(|e| e.is_shared()).count()
    }

    fn ensure_pages(&mut self, layer: usize, new_len: usize) -> Result<()> {
        let needed = new_len.div_ceil(PAGE_SLOTS);
        while self.pages[layer].len() < needed {
            let page = self.arena.alloc(self.row_width())?;
            self.pages[layer].push(PageEntry::Owned(page.into()));
        }
        Ok(())
    }

    fn release_excess(&mut self, layer: usize) {
        let needed = self.lens[layer].div_ceil(PAGE_SLOTS);
        let rw = self.row_width();
        while self.pages[layer].len() > needed {
            match self.pages[layer].pop().unwrap() {
                PageEntry::Owned(page) => self.arena.free(rw, page),
                // refcount drop: the last reader (possibly the prefix
                // tree) returns the page
                PageEntry::Shared(_) => {}
            }
        }
    }

    /// Append one layer's window K/V rows (from a score program's output,
    /// shaped `[H, W, Dh]` with `n_valid <= W` rows valid) at the tail.
    /// Head-major pages make this a per-(page-run, head) block copy.
    pub fn append_layer(
        &mut self,
        layer: usize,
        win_k: &[f32],
        win_v: &[f32],
        w: usize,
        n_valid: usize,
        first_pos: u64,
    ) -> Result<()> {
        let len = self.lens[layer];
        if len + n_valid > self.c {
            bail!("cache overflow: layer {layer} len {len} + {n_valid} > C {}", self.c);
        }
        debug_assert_eq!(win_k.len(), self.h * w * self.dh);
        self.ensure_pages(layer, len + n_valid)?;
        let (h, dh) = (self.h, self.dh);
        let rw = self.row_width();
        let mut i = 0;
        while i < n_valid {
            let slot = len + i;
            let sp = slot % PAGE_SLOTS;
            let run = (PAGE_SLOTS - sp).min(n_valid - i);
            let page =
                owned_page(&self.arena, rw, self.id, &mut self.pages[layer], slot / PAGE_SLOTS)?;
            for hh in 0..h {
                let src = (hh * w + i) * dh;
                let dst = (hh * PAGE_SLOTS + sp) * dh;
                page.k[dst..dst + run * dh].copy_from_slice(&win_k[src..src + run * dh]);
                page.v[dst..dst + run * dh].copy_from_slice(&win_v[src..src + run * dh]);
            }
            i += run;
        }
        self.lens[layer] = len + n_valid;
        for i in 0..n_valid {
            self.positions[layer].push(first_pos + i as u64);
            self.mass[layer].push(0.0);
        }
        self.mark_dirty(layer, len, len + n_valid);
        Ok(())
    }

    /// Order-preserving compaction: keep exactly the slots in `keep`
    /// (sorted, unique, all < lens[layer]) for one layer. Rows whose slot
    /// index is unchanged are untouched; the rest move once per head
    /// (in-page `copy_within`, or a direct cross-page copy — the destination
    /// page index is always strictly below the source's), and emptied tail
    /// pages return to the arena. Everything from the first moved slot to
    /// the old length is marked dirty (covering the vacated tail).
    pub fn retain_slots(&mut self, layer: usize, keep: &[usize]) -> Result<()> {
        let len = self.lens[layer];
        let mut prev: Option<usize> = None;
        for &s in keep {
            if s >= len {
                bail!("retain_slots: slot {s} >= len {len}");
            }
            if let Some(p) = prev {
                if s <= p {
                    bail!("retain_slots: indices must be strictly increasing");
                }
            }
            prev = Some(s);
        }
        // first slot whose content changes (moved row or vacated tail)
        let first_change = keep
            .iter()
            .enumerate()
            .position(|(dst_i, &src_i)| dst_i != src_i)
            .unwrap_or(keep.len());
        let (h, dh) = (self.h, self.dh);
        let rw = self.row_width();
        // compaction is precision-preserving: remember which pages were Q8
        // on entry so the move pass (which promotes its destinations to
        // f32) can re-demote them afterwards — without this, every
        // compaction would thaw the whole cold region back to f32
        let prior_q8: Vec<bool> = if self.quant {
            self.pages[layer].iter().map(|e| e.precision() == Precision::Q8).collect()
        } else {
            Vec::new()
        };
        // copy-on-write every page a move will write into, BEFORE moving:
        // CoW preserves content, so doing it up front (even on alloc
        // failure partway) never leaves a half-moved layer
        for (dst_i, &src_i) in keep.iter().enumerate() {
            if dst_i != src_i {
                owned_page(&self.arena, rw, self.id, &mut self.pages[layer], dst_i / PAGE_SLOTS)?;
            }
        }
        for (dst_i, &src_i) in keep.iter().enumerate() {
            if dst_i == src_i {
                continue; // prefix already in place
            }
            let (spi, so) = (src_i / PAGE_SLOTS, src_i % PAGE_SLOTS);
            let (dpi, dof) = (dst_i / PAGE_SLOTS, dst_i % PAGE_SLOTS);
            if spi == dpi {
                let page = self.pages[layer][spi].owned_mut();
                for hh in 0..h {
                    let s = (hh * PAGE_SLOTS + so) * dh;
                    let d = (hh * PAGE_SLOTS + dof) * dh;
                    page.k.copy_within(s..s + dh, d);
                    page.v.copy_within(s..s + dh, d);
                }
            } else {
                // dst_i < src_i for strictly-increasing keep, so dpi < spi
                let (head_pages, tail_pages) = self.pages[layer].split_at_mut(spi);
                let spage = tail_pages[0].page();
                let dpage = head_pages[dpi].owned_mut();
                for hh in 0..h {
                    let s = (hh * PAGE_SLOTS + so) * dh;
                    let d = (hh * PAGE_SLOTS + dof) * dh;
                    match spage {
                        PageData::F32(sp) => {
                            dpage.k[d..d + dh].copy_from_slice(&sp.k[s..s + dh]);
                            dpage.v[d..d + dh].copy_from_slice(&sp.v[s..s + dh]);
                        }
                        PageData::Q8(q) => {
                            // a cold source row moving down: dequantize on
                            // read (the source page itself is untouched)
                            q.k_run_into(hh, s, &mut dpage.k[d..d + dh]);
                            q.v_run_into(hh, s, &mut dpage.v[d..d + dh]);
                        }
                    }
                }
            }
        }
        self.positions[layer] = keep.iter().map(|&s| self.positions[layer][s]).collect();
        self.mass[layer] = keep.iter().map(|&s| self.mass[layer][s]).collect();
        self.lens[layer] = keep.len();
        self.mark_dirty(layer, first_change, len);
        self.release_excess(layer);
        // re-demote the cold region the move pass promoted (still guarded
        // by the cutoff/sink/tail rules — a page that pulled hot-tail slots
        // down stays f32 until it ages out again). Compaction shifts content
        // toward lower page indexes, so this scans every page from the first
        // changed one rather than trusting old indexes; each re-encode
        // changes stored bytes (fresh scales), so it marks the whole page
        // dirty like any other demotion. Skipped entirely when no page was
        // Q8 on entry — a plain compaction never quantizes ahead of
        // [`Self::demote_cold`].
        if prior_q8.iter().any(|&b| b) {
            for pi in first_change / PAGE_SLOTS..self.pages[layer].len() {
                if self.try_demote_page(layer, pi, self.quant_cutoff) {
                    self.mark_dirty(layer, pi * PAGE_SLOTS, (pi + 1) * PAGE_SLOTS);
                }
            }
        }
        Ok(())
    }

    /// Drop the tail so exactly `new_len` slots remain (the engine's rollback
    /// of over-generated decode steps). Emptied pages return to the arena and
    /// the dropped range is marked dirty so the next gather zero-fills it.
    pub fn truncate_layer(&mut self, layer: usize, new_len: usize) -> Result<()> {
        if new_len > self.lens[layer] {
            bail!("truncate_layer: {new_len} > len {}", self.lens[layer]);
        }
        let old_len = self.lens[layer];
        self.lens[layer] = new_len;
        self.positions[layer].truncate(new_len);
        self.mass[layer].truncate(new_len);
        self.mark_dirty(layer, new_len, old_len);
        self.release_excess(layer);
        Ok(())
    }

    /// Merge a generate program's output state (device-shaped `[L, H, C, Dh]`
    /// buffers with `appended` new slots per layer) back into the paged
    /// store. Only the appended rows are copied — resident rows were uploaded
    /// from this cache and are unchanged on the device. `first_pos` is the
    /// engine's authoritative stream position of the first appended token:
    /// it cannot be inferred from `positions.last() + 1`, which drifts
    /// whenever the recency tail was evicted (any `n_recent = 0` config).
    ///
    /// The appended ranges are marked dirty; when the caller hands the same
    /// device buffers to [`super::transfer::ScratchPool::absorb`] right
    /// after, that absorb marks them clean again (the device output *is* the
    /// current dense image) and the next gather for this cache is a no-op.
    pub fn replace_from_device(
        &mut self,
        k: &[f32],
        v: &[f32],
        lens: &[i32],
        appended: usize,
        first_pos: u64,
    ) -> Result<()> {
        debug_assert_eq!(k.len(), self.dense_elems());
        let (h, c, dh) = (self.h, self.c, self.dh);
        for l in 0..self.l {
            let new_len = lens[l] as usize;
            let old_len = self.lens[l];
            if new_len != old_len + appended {
                bail!("replace_from_device: layer {l} len {new_len} != {old_len} + {appended}");
            }
            if let Some(&last) = self.positions[l].last() {
                if first_pos <= last {
                    bail!("replace_from_device: first_pos {first_pos} <= resident tail {last}");
                }
            }
            self.ensure_pages(l, new_len)?;
            let rw = self.row_width();
            let mut slot = old_len;
            while slot < new_len {
                let sp = slot % PAGE_SLOTS;
                let run = (PAGE_SLOTS - sp).min(new_len - slot);
                let page =
                    owned_page(&self.arena, rw, self.id, &mut self.pages[l], slot / PAGE_SLOTS)?;
                for hh in 0..h {
                    let src = ((l * h + hh) * c + slot) * dh;
                    let dst = (hh * PAGE_SLOTS + sp) * dh;
                    page.k[dst..dst + run * dh].copy_from_slice(&k[src..src + run * dh]);
                    page.v[dst..dst + run * dh].copy_from_slice(&v[src..src + run * dh]);
                }
                slot += run;
            }
            for i in 0..appended {
                self.positions[l].push(first_pos + i as u64);
                self.mass[l].push(0.0);
            }
            self.lens[l] = new_len;
            self.mark_dirty(l, old_len, new_len);
        }
        Ok(())
    }

    /// Copy slots `[lo, hi)` of one layer (all heads) into a dense
    /// `[L, H, C, Dh]` image; `hi <= lens[layer]`. Head-major pages make each
    /// (page-run, head) transfer one contiguous `run * Dh` block on both
    /// sides; Q8 pages dequantize into the image (per-head contiguous runs —
    /// one scale lookup per run) instead of memcpy. Returns f32 elements
    /// copied per buffer side (K and V each) plus nanoseconds spent
    /// dequantizing.
    fn copy_slots_into(
        &self,
        layer: usize,
        lo: usize,
        hi: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> (u64, u64) {
        let (h, c, dh) = (self.h, self.c, self.dh);
        let mut copied = 0u64;
        let mut dequant_ns = 0u64;
        let mut slot = lo;
        while slot < hi {
            let sp = slot % PAGE_SLOTS;
            let run = (PAGE_SLOTS - sp).min(hi - slot);
            match self.pages[layer][slot / PAGE_SLOTS].page() {
                PageData::F32(page) => {
                    for hh in 0..h {
                        let src = (hh * PAGE_SLOTS + sp) * dh;
                        let dst = ((layer * h + hh) * c + slot) * dh;
                        k_out[dst..dst + run * dh].copy_from_slice(&page.k[src..src + run * dh]);
                        v_out[dst..dst + run * dh].copy_from_slice(&page.v[src..src + run * dh]);
                    }
                }
                PageData::Q8(q) => {
                    let t0 = Instant::now();
                    for hh in 0..h {
                        let src = (hh * PAGE_SLOTS + sp) * dh;
                        let dst = ((layer * h + hh) * c + slot) * dh;
                        q.k_run_into(hh, src, &mut k_out[dst..dst + run * dh]);
                        q.v_run_into(hh, src, &mut v_out[dst..dst + run * dh]);
                    }
                    dequant_ns += t0.elapsed().as_nanos() as u64;
                }
            }
            copied += (h * run * dh) as u64;
            slot += run;
        }
        (copied, dequant_ns)
    }

    /// Zero slots `[lo, hi)` of one layer (all heads) in a dense image.
    /// Returns f32 elements written per buffer side.
    fn zero_slots_in(
        &self,
        layer: usize,
        lo: usize,
        hi: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> u64 {
        if lo >= hi {
            return 0;
        }
        let (h, c, dh) = (self.h, self.c, self.dh);
        for hh in 0..h {
            let dst = ((layer * h + hh) * c + lo) * dh;
            let n = (hi - lo) * dh;
            k_out[dst..dst + n].fill(0.0);
            v_out[dst..dst + n].fill(0.0);
        }
        (h * (hi - lo) * dh) as u64
    }

    /// Stage slots `[lo, hi)` of one (layer, head) as a COMPACT contiguous
    /// run of `(hi - lo) * Dh` floats per side: valid slots come from the
    /// pages, slots at or beyond `lens[layer]` are zero-filled (matching the
    /// dense image's padding invariant). The device-residency tier uses this
    /// to reconcile a dirty slot range onto a resident device image with one
    /// partial upload per (layer, head) — the dense `[L, H, C, Dh]` layout
    /// makes exactly that run contiguous on the device side.
    pub fn stage_rows(
        &self,
        layer: usize,
        head: usize,
        lo: usize,
        hi: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let dh = self.dh;
        debug_assert!(lo <= hi && hi <= self.c);
        debug_assert_eq!(k_out.len(), (hi - lo) * dh);
        debug_assert_eq!(v_out.len(), (hi - lo) * dh);
        let valid_hi = hi.min(self.lens[layer]);
        let mut slot = lo;
        while slot < valid_hi {
            let sp = slot % PAGE_SLOTS;
            let run = (PAGE_SLOTS - sp).min(valid_hi - slot);
            let src = (head * PAGE_SLOTS + sp) * dh;
            let dst = (slot - lo) * dh;
            match self.pages[layer][slot / PAGE_SLOTS].page() {
                PageData::F32(page) => {
                    k_out[dst..dst + run * dh].copy_from_slice(&page.k[src..src + run * dh]);
                    v_out[dst..dst + run * dh].copy_from_slice(&page.v[src..src + run * dh]);
                }
                PageData::Q8(q) => {
                    q.k_run_into(head, src, &mut k_out[dst..dst + run * dh]);
                    q.v_run_into(head, src, &mut v_out[dst..dst + run * dh]);
                }
            }
            slot += run;
        }
        let zero_from = (valid_hi.max(lo) - lo) * dh;
        k_out[zero_from..].fill(0.0);
        v_out[zero_from..].fill(0.0);
    }

    /// Write the complete dense `[L, H, C, Dh]` image (valid rows + zero
    /// padding) into caller-provided buffers, touching every element exactly
    /// once. Does not change dirty state — callers that keep the image as a
    /// synced scratch call [`Self::mark_synced`] afterwards.
    pub fn gather_full_into(&self, k_out: &mut [f32], v_out: &mut [f32]) -> GatherBytes {
        assert_eq!(k_out.len(), self.dense_elems());
        assert_eq!(v_out.len(), self.dense_elems());
        let mut out = GatherBytes::default();
        for l in 0..self.l {
            let len = self.lens[l];
            let (copied, ns) = self.copy_slots_into(l, 0, len, k_out, v_out);
            out.copied += 2 * 4 * copied;
            out.dequant_ns += ns;
            out.zeroed += 2 * 4 * self.zero_slots_in(l, len, self.c, k_out, v_out);
        }
        out
    }

    /// Re-copy only the dirty slot ranges into a dense image that was synced
    /// with this cache at the last [`Self::mark_synced`] point: valid dirty
    /// slots come from the pages, dirty slots beyond the current length are
    /// zero-filled (the cache shrank since the image was made). The caller
    /// must guarantee the buffers hold that synced image — the transfer
    /// layer's (id, sync_gen) check. Does not change dirty state.
    pub fn gather_dirty_into(&self, k_out: &mut [f32], v_out: &mut [f32]) -> GatherBytes {
        assert_eq!(k_out.len(), self.dense_elems());
        assert_eq!(v_out.len(), self.dense_elems());
        let mut out = GatherBytes::default();
        for l in 0..self.l {
            let Some((lo, hi)) = self.dirty[l] else {
                continue;
            };
            let len = self.lens[l];
            let copy_hi = hi.min(len);
            if lo < copy_hi {
                let (copied, ns) = self.copy_slots_into(l, lo, copy_hi, k_out, v_out);
                out.copied += 2 * 4 * copied;
                out.dequant_ns += ns;
            }
            let zero_lo = lo.max(len);
            if zero_lo < hi {
                out.zeroed += 2 * 4 * self.zero_slots_in(l, zero_lo, hi, k_out, v_out);
            }
        }
        out
    }

    /// Materialize a fresh device-contiguous `[L, H, C, Dh]` K/V pair
    /// (invalid slots zero-padded). Allocates two full buffers per call —
    /// this is the reference/cold path; the serving hot path goes through
    /// [`super::transfer::ScratchPool::gather`] instead.
    pub fn gather_dense(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.dense_elems();
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for l in 0..self.l {
            let _ = self.copy_slots_into(l, 0, self.lens[l], &mut k, &mut v);
        }
        (k, v)
    }

    /// Add per-slot attention mass from a scored program (`mass_row` is the
    /// device `[C+W]` or `[C]` row for `layer`; only the first lens entries
    /// apply to resident slots).
    pub fn add_mass(&mut self, layer: usize, mass_row: &[f32]) {
        let n = self.lens[layer].min(mass_row.len());
        for i in 0..n {
            self.mass[layer][i] += mass_row[i] as f64;
        }
    }

    /// Quantize one eligible page in place: owned f32, not the attention
    /// sink (page 0), not the hot tail (the last or partial page), and
    /// every resident token strictly older than `cutoff`. Returns whether
    /// it demoted. Does NOT consult or mark dirty state — callers own
    /// that: [`Self::demote_cold`] checks its dirty snapshot first and
    /// marks after; compaction's re-demote pass runs inside an interval it
    /// already marked.
    fn try_demote_page(&mut self, layer: usize, pi: usize, cutoff: u64) -> bool {
        if !self.quant || pi == 0 {
            return false;
        }
        let n_pages = self.pages[layer].len();
        if pi + 1 >= n_pages || (pi + 1) * PAGE_SLOTS > self.lens[layer] {
            return false;
        }
        let entry = &self.pages[layer][pi];
        if entry.is_shared() || entry.precision() != Precision::F32 {
            return false;
        }
        if self.positions[layer][(pi + 1) * PAGE_SLOTS - 1] >= cutoff {
            return false;
        }
        self.quantize_owned_page(layer, pi, PAGE_SLOTS);
        true
    }

    /// Replace an owned f32 page with its Q8 encoding (unchecked arena
    /// alloc: the f32 page freed right after makes this a net shrink).
    fn quantize_owned_page(&mut self, layer: usize, pi: usize, valid_slots: usize) {
        let rw = self.row_width();
        let mut q =
            self.arena.alloc_q8(rw, self.h, false).expect("unchecked q8 alloc cannot fail");
        q.encode(self.pages[layer][pi].page().expect_f32(), valid_slots);
        obs::record(EventKind::QuantDemote, self.id, 0, layer as i64, pi as i64);
        let old =
            std::mem::replace(&mut self.pages[layer][pi], PageEntry::Owned(PageData::Q8(q)));
        let PageEntry::Owned(data) = old else {
            unreachable!("caller checked owned");
        };
        self.arena.free(rw, data);
    }

    /// Distance-based demotion (the `--kv-quant cold-q8` engine hook):
    /// quantize every eligible page whose tokens are all strictly older
    /// than `cutoff` (the engine passes
    /// `stream_pos - quantize_after_windows * w`). Skips the attention-sink
    /// page (page 0), the hot tail (last/partial page), shared pages
    /// (frozen snapshots quantize at freeze time), already-Q8 pages, and
    /// any page overlapping an open dirty range — its slots were never
    /// materialized into a synced image, so re-encoding them now would
    /// conflate two generations; they demote after the next sync point.
    /// Each demotion changes stored values and therefore marks the page's
    /// slots dirty exactly once. Returns the number of pages demoted. A
    /// no-op (returning 0) when quantization is off.
    pub fn demote_cold(&mut self, cutoff: u64) -> usize {
        if !self.quant {
            return 0;
        }
        self.quant_cutoff = self.quant_cutoff.max(cutoff);
        let cutoff = self.quant_cutoff;
        let mut demoted = 0;
        for layer in 0..self.l {
            let dirty0 = self.dirty[layer];
            let n_pages = self.pages[layer].len();
            for pi in 1..n_pages.saturating_sub(1) {
                if let Some((lo, hi)) = dirty0 {
                    if lo < (pi + 1) * PAGE_SLOTS && hi > pi * PAGE_SLOTS {
                        continue;
                    }
                }
                if self.try_demote_page(layer, pi, cutoff) {
                    self.mark_dirty(layer, pi * PAGE_SLOTS, (pi + 1) * PAGE_SLOTS);
                    demoted += 1;
                }
            }
        }
        demoted
    }

    /// Freeze every page of this cache into refcounted shared pages (in
    /// place — this cache keeps using them; its next mutation of any frozen
    /// page goes through CoW) and return per-layer handles for the prefix
    /// tree. Pages already shared just hand out another handle. No bytes
    /// move and arena accounting is unchanged.
    ///
    /// With quantization enabled, owned f32 pages are quantized FIRST, so
    /// prefix snapshots freeze directly to Q8 (~4x more reusable prefixes
    /// under the same `prefix_pool_bytes`) — frozen pages are immutable and
    /// read-mostly, exactly the cold tier. The re-encoded slots are marked
    /// dirty (once) for the donor's own next gather.
    pub fn freeze_pages(&mut self) -> Vec<Vec<SharedPage>> {
        if self.quant {
            for layer in 0..self.l {
                for pi in 0..self.pages[layer].len() {
                    let entry = &self.pages[layer][pi];
                    if entry.is_shared() || entry.precision() == Precision::Q8 {
                        continue;
                    }
                    let lo = pi * PAGE_SLOTS;
                    let hi = ((pi + 1) * PAGE_SLOTS).min(self.c);
                    let valid = self.lens[layer].saturating_sub(lo).min(PAGE_SLOTS);
                    self.quantize_owned_page(layer, pi, valid);
                    self.mark_dirty(layer, lo, hi);
                }
            }
        }
        let rw = self.row_width();
        let arena = self.arena.clone();
        self.pages
            .iter_mut()
            .map(|table| table.iter_mut().map(|e| e.freeze(&arena, rw)).collect())
            .collect()
    }

    /// Install a frozen prefix into this EMPTY cache (the fork path): adopt
    /// the shared page handles plus occupancy bookkeeping without copying a
    /// byte — the arena charged these pages once, at the donor's original
    /// allocation. Everything is validated before anything is installed, so
    /// a failed adopt leaves the cache untouched. All adopted slots are
    /// marked dirty (the fork has a fresh id, so its first gather is a full
    /// one regardless).
    pub fn adopt_shared(
        &mut self,
        pages: &[Vec<SharedPage>],
        lens: &[usize],
        positions: &[Vec<u64>],
        mass: &[Vec<f64>],
    ) -> Result<()> {
        if self.lens.iter().any(|&n| n != 0) {
            bail!("adopt_shared: cache is not empty");
        }
        if pages.len() != self.l || lens.len() != self.l {
            bail!("adopt_shared: layer count mismatch ({} != {})", pages.len(), self.l);
        }
        if positions.len() != self.l || mass.len() != self.l {
            bail!("adopt_shared: bookkeeping layer count mismatch");
        }
        let rw = self.row_width();
        for l in 0..self.l {
            if lens[l] > self.c {
                bail!("adopt_shared: layer {l} len {} > capacity {}", lens[l], self.c);
            }
            if pages[l].len() != lens[l].div_ceil(PAGE_SLOTS) {
                bail!(
                    "adopt_shared: layer {l} has {} pages for {} slots",
                    pages[l].len(),
                    lens[l]
                );
            }
            if positions[l].len() != lens[l] || mass[l].len() != lens[l] {
                bail!("adopt_shared: layer {l} bookkeeping length mismatch");
            }
            if pages[l].iter().any(|sp| sp.row_width() != rw) {
                bail!("adopt_shared: layer {l} row-width mismatch");
            }
        }
        for l in 0..self.l {
            self.pages[l] = pages[l].iter().map(|sp| PageEntry::Shared(sp.clone())).collect();
            self.lens[l] = lens[l];
            self.positions[l] = positions[l].clone();
            self.mass[l] = mass[l].clone();
            self.mark_dirty(l, 0, lens[l]);
        }
        Ok(())
    }

    /// Consistency invariants (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<()> {
        for l in 0..self.l {
            if self.lens[l] > self.c {
                bail!("len > capacity");
            }
            if self.positions[l].len() != self.lens[l] || self.mass[l].len() != self.lens[l] {
                bail!("bookkeeping length mismatch");
            }
            if self.pages[l].len() != self.lens[l].div_ceil(PAGE_SLOTS) {
                bail!(
                    "page table mismatch in layer {l}: {} pages for {} slots",
                    self.pages[l].len(),
                    self.lens[l]
                );
            }
            for w in self.positions[l].windows(2) {
                if w[0] >= w[1] {
                    bail!("positions not strictly increasing in layer {l}");
                }
            }
            if let Some((lo, hi)) = self.dirty[l] {
                if lo >= hi || hi > self.c {
                    bail!("malformed dirty range [{lo}, {hi}) in layer {l} (C {})", self.c);
                }
            }
        }
        Ok(())
    }
}

impl KvCache {
    /// Fallible deep copy: fresh pages from the same arena and a fresh id
    /// (no scratch image can match the clone, so its first gather is a full
    /// one). Arena-budget exhaustion mid-copy surfaces as a typed
    /// [`CallError`] of kind `Oom` — not retryable, so a fork under memory
    /// pressure quarantines one sequence instead of killing the process —
    /// and the partially built clone's pages return to the arena via `Drop`.
    pub fn try_clone(&self) -> Result<Self> {
        let mut out = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        out.quant = self.quant;
        out.quant_cutoff = self.quant_cutoff;
        let rw = self.row_width();
        let oom =
            |e| CallError::oom(format!("kv-arena budget exceeded while cloning KvCache: {e}"));
        for l in 0..self.l {
            for entry in &self.pages[l] {
                // clones preserve each page's precision tier: a cold Q8
                // page stays Q8 (same bytes, no extra error — the int8
                // payload and scales copy verbatim)
                let data = match entry.page() {
                    PageData::F32(page) => {
                        let mut p = out.arena.alloc(rw).map_err(oom)?;
                        p.k.copy_from_slice(&page.k);
                        p.v.copy_from_slice(&page.v);
                        PageData::F32(p)
                    }
                    PageData::Q8(q) => {
                        let mut p = out.arena.alloc_q8(rw, self.h, true).map_err(oom)?;
                        p.k.copy_from_slice(&q.k);
                        p.v.copy_from_slice(&q.v);
                        p.k_scales.copy_from_slice(&q.k_scales);
                        p.v_scales.copy_from_slice(&q.v_scales);
                        PageData::Q8(p)
                    }
                };
                out.pages[l].push(PageEntry::Owned(data));
            }
        }
        out.lens = self.lens.clone();
        out.positions = self.positions.clone();
        out.mass = self.mass.clone();
        for l in 0..out.l {
            let len = out.lens[l];
            out.mark_dirty(l, 0, len);
        }
        Ok(out)
    }
}

impl Clone for KvCache {
    /// Infallible facade over [`KvCache::try_clone`] for bench/test code
    /// that clones under a known-sufficient budget. Anything that can run
    /// under arena pressure (the serving fork path) must use `try_clone`
    /// and propagate the typed OOM instead.
    fn clone(&self) -> Self {
        self.try_clone().expect("kv-arena budget exceeded while cloning KvCache")
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        let rw = self.row_width();
        for table in &mut self.pages {
            for entry in table.drain(..) {
                match entry {
                    PageEntry::Owned(page) => self.arena.free(rw, page),
                    // refcount drop: freed by the last reader
                    PageEntry::Shared(_) => {}
                }
            }
        }
    }
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("l", &self.l)
            .field("h", &self.h)
            .field("c", &self.c)
            .field("dh", &self.dh)
            .field("lens", &self.lens)
            .field("resident_bytes", &self.resident_bytes())
            .field("dirty", &self.dirty)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::PropRunner;
    use crate::util::rng::Xoshiro256;

    fn filled(l: usize, h: usize, c: usize, dh: usize, n: usize) -> KvCache {
        let mut kv = KvCache::with_arena(KvArena::new(), l, h, c, dh);
        for layer in 0..l {
            let w = n;
            let mut wk = vec![0.0f32; h * w * dh];
            let mut wv = vec![0.0f32; h * w * dh];
            for hh in 0..h {
                for i in 0..w {
                    for d in 0..dh {
                        wk[(hh * w + i) * dh + d] = (layer * 1000 + hh * 100 + i) as f32;
                        wv[(hh * w + i) * dh + d] = -((layer * 1000 + hh * 100 + i) as f32);
                    }
                }
            }
            kv.append_layer(layer, &wk, &wv, w, n, 0).unwrap();
        }
        kv
    }

    #[test]
    fn append_and_invariants() {
        let kv = filled(2, 2, 16, 4, 5);
        assert_eq!(kv.lens, vec![5, 5]);
        kv.check_invariants().unwrap();
        assert_eq!(kv.kv_bytes(), 2 * 2 * 2 * 5 * 4 * 4);
        // 5 slots -> one page per layer; resident bytes are page-granular
        assert_eq!(kv.resident_bytes(), 2 * Page::bytes(2 * 4));
    }

    #[test]
    fn append_overflow_fails() {
        let mut kv = KvCache::with_arena(KvArena::new(), 1, 1, 4, 2);
        let w = vec![0.0; 6 * 2];
        assert!(kv.append_layer(0, &w, &w, 6, 6, 0).is_err());
    }

    #[test]
    fn retain_gathers_rows() {
        let mut kv = filled(2, 2, 16, 4, 6);
        kv.retain_slots(0, &[0, 2, 5]).unwrap();
        assert_eq!(kv.lens[0], 3);
        assert_eq!(kv.positions[0], vec![0, 2, 5]);
        // head 1 row 1 should now hold original slot 2's value (=102)
        assert_eq!(kv.row_k(0, 1, 1)[0], 102.0);
        assert_eq!(kv.row_v(0, 1, 1)[0], -102.0);
        // layer 1 untouched
        assert_eq!(kv.lens[1], 6);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retain_rejects_bad_indices() {
        let mut kv = filled(1, 1, 8, 2, 4);
        assert!(kv.retain_slots(0, &[2, 1]).is_err());
        assert!(kv.retain_slots(0, &[0, 9]).is_err());
        assert!(kv.retain_slots(0, &[1, 1]).is_err());
    }

    #[test]
    fn mass_tracking() {
        let mut kv = filled(1, 1, 8, 2, 4);
        kv.add_mass(0, &[1.0, 2.0, 3.0, 4.0, 99.0]);
        assert_eq!(kv.mass[0], vec![1.0, 2.0, 3.0, 4.0]);
        kv.retain_slots(0, &[1, 3]).unwrap();
        assert_eq!(kv.mass[0], vec![2.0, 4.0]);
    }

    #[test]
    fn retain_across_page_boundaries_frees_tail_pages() {
        // 40 slots = 3 pages; keep a sparse 10 -> 1 page
        let mut kv = filled(1, 2, 64, 4, 40);
        let arena_before = kv.resident_bytes();
        assert_eq!(kv.n_pages(0), 3);
        assert_eq!(arena_before, 3 * Page::bytes(2 * 4));
        let keep: Vec<usize> = (0..40).step_by(4).collect();
        kv.retain_slots(0, &keep).unwrap();
        assert_eq!(kv.lens[0], 10);
        assert_eq!(kv.n_pages(0), 1);
        kv.check_invariants().unwrap();
        // moved rows carry their content (slot 5 now holds original slot 20)
        assert_eq!(kv.row_k(0, 1, 5)[0], 120.0);
        assert_eq!(kv.positions[0], (0..40).step_by(4).collect::<Vec<u64>>());
    }

    #[test]
    fn truncate_layer_drops_tail_and_pages() {
        let mut kv = filled(1, 1, 64, 2, 33); // 3 pages
        kv.add_mass(0, &[1.0; 33]);
        kv.truncate_layer(0, 16).unwrap(); // exactly one page
        assert_eq!(kv.lens[0], 16);
        assert_eq!(kv.n_pages(0), 1);
        assert_eq!(kv.positions[0].len(), 16);
        assert_eq!(kv.mass[0].len(), 16);
        kv.check_invariants().unwrap();
        assert!(kv.truncate_layer(0, 17).is_err());
    }

    #[test]
    fn replace_from_device_uses_stream_counter_not_tail_inference() {
        // regression: after evicting the recency tail, the next position must
        // come from the engine's stream counter, not `positions.last() + 1`
        let mut kv = filled(1, 1, 8, 2, 6); // positions 0..=5
        kv.retain_slots(0, &[0, 1]).unwrap(); // tail evicted
        let mut k = vec![0.0f32; 8 * 2];
        let mut v = vec![0.0f32; 8 * 2];
        k[2 * 2] = 7.5; // slot 2, head 0, d 0
        v[2 * 2] = -7.5;
        kv.replace_from_device(&k, &v, &[3], 1, 6).unwrap();
        // the appended slot is stream token 6; the old inference gave 2
        assert_eq!(kv.positions[0], vec![0, 1, 6]);
        assert_eq!(kv.row_k(0, 0, 2)[0], 7.5);
        assert_eq!(kv.row_v(0, 0, 2)[0], -7.5);
        kv.check_invariants().unwrap();
        // non-monotone first_pos is rejected
        let err = kv.replace_from_device(&k, &v, &[4], 1, 3).unwrap_err();
        assert!(format!("{err}").contains("first_pos"));
    }

    #[test]
    fn drop_returns_pages_to_arena() {
        let arena = KvArena::new();
        {
            let kv = {
                let mut kv = KvCache::with_arena(arena.clone(), 2, 1, 64, 2);
                let w = vec![0.0f32; 20 * 2];
                kv.append_layer(0, &w, &w, 20, 20, 0).unwrap();
                kv.append_layer(1, &w, &w, 20, 20, 0).unwrap();
                kv
            };
            assert_eq!(arena.stats().bytes_in_use, 4 * Page::bytes(2));
            drop(kv);
        }
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, 0);
        assert_eq!(st.bytes_pooled, 4 * Page::bytes(2));
    }

    #[test]
    fn clone_is_deep_with_fresh_identity() {
        let kv = filled(1, 1, 16, 2, 5);
        let mut c = kv.clone();
        assert_ne!(kv.id(), c.id(), "clone must get a fresh scratch-pool id");
        c.retain_slots(0, &[0, 4]).unwrap();
        assert_eq!(kv.lens[0], 5);
        assert_eq!(c.lens[0], 2);
        assert_eq!(kv.row_k(0, 0, 1)[0], 1.0);
        assert_eq!(c.row_k(0, 0, 1)[0], 4.0);
    }

    #[test]
    fn try_clone_surfaces_typed_oom_and_leaks_nothing() {
        use crate::runtime::error::{classify, CallErrorKind};
        let arena = KvArena::new();
        let mut kv = KvCache::with_arena(arena.clone(), 1, 1, 64, 2);
        let n = 2 * PAGE_SLOTS; // two pages, so the clone OOMs mid-copy
        let w: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        kv.append_layer(0, &w, &w, n, n, 0).unwrap();
        let used = arena.stats().bytes_in_use;

        // room for only ONE of the clone's two pages
        arena.set_budget(Some(used + Page::bytes(2)));
        let err = kv.try_clone().unwrap_err();
        assert_eq!(classify(&err), CallErrorKind::Oom, "budget exhaustion must classify as OOM");
        assert!(!CallErrorKind::Oom.retryable(), "OOM quarantines; retry cannot help");
        assert!(format!("{err:#}").contains("cloning KvCache"), "context lost: {err:#}");
        // the half-built clone's page went back: occupancy is unchanged
        assert_eq!(arena.stats().bytes_in_use, used, "failed try_clone must not leak pages");

        // with the budget lifted the same clone succeeds, deep and exact
        arena.set_budget(None);
        let c = kv.try_clone().unwrap();
        assert_ne!(kv.id(), c.id());
        let (k1, v1) = kv.gather_dense();
        let (k2, v2) = c.gather_dense();
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn dirty_ranges_track_mutations_and_sync() {
        let mut kv = filled(2, 1, 64, 2, 10);
        // appends since construction: everything dirty
        assert_eq!(kv.dirty_range(0), Some((0, 10)));
        assert!(!kv.is_clean());
        kv.mark_synced();
        assert!(kv.is_clean());
        let g0 = kv.sync_gen();

        // pure append dirties exactly the appended range
        let w = vec![0.0f32; 3 * 2];
        kv.append_layer(0, &w, &w, 3, 3, 10).unwrap();
        assert_eq!(kv.dirty_range(0), Some((10, 13)));
        assert_eq!(kv.dirty_range(1), None, "other layers stay clean");

        // truncate dirties the dropped tail
        kv.truncate_layer(0, 11).unwrap();
        assert_eq!(kv.dirty_range(0), Some((10, 13)), "merged with append range");

        // retain dirties from the first moved slot through the old length
        kv.mark_synced();
        kv.retain_slots(0, &[0, 1, 5, 6]).unwrap();
        assert_eq!(kv.dirty_range(0), Some((2, 11)));

        // identity retain leaves the layer clean
        kv.mark_synced();
        kv.retain_slots(0, &[0, 1, 2, 3]).unwrap();
        assert_eq!(kv.dirty_range(0), None);
        assert!(kv.sync_gen() > g0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn gather_dirty_matches_full_after_shrink() {
        // a synced image updated through gather_dirty_into must equal a
        // from-scratch gather, including zero-fill of the shrunk tail
        let mut kv = filled(2, 2, 32, 3, 20);
        let n = kv.dense_elems();
        let (mut ik, mut iv) = (vec![0.0f32; n], vec![0.0f32; n]);
        kv.gather_full_into(&mut ik, &mut iv);
        kv.mark_synced();

        kv.retain_slots(0, &[0, 3, 7]).unwrap();
        kv.truncate_layer(1, 4).unwrap();
        let gb = kv.gather_dirty_into(&mut ik, &mut iv);
        assert!(gb.zeroed > 0, "shrunk regions must be zero-filled");

        let (fk, fv) = kv.gather_dense();
        assert_eq!(ik, fk);
        assert_eq!(iv, fv);
    }

    #[test]
    fn stage_rows_matches_dense_image_and_zero_fills() {
        let mut kv = filled(2, 2, 32, 3, 20);
        kv.truncate_layer(0, 12).unwrap();
        let (dk, dv) = kv.gather_dense();
        let (c, dh) = (kv.c, kv.dh);
        // a range straddling a page boundary AND the valid length (12)
        let (lo, hi) = (9, 18);
        for layer in 0..kv.l {
            for head in 0..kv.h {
                let n = (hi - lo) * dh;
                let mut sk = vec![f32::NAN; n];
                let mut sv = vec![f32::NAN; n];
                kv.stage_rows(layer, head, lo, hi, &mut sk, &mut sv);
                let off = ((layer * kv.h + head) * c + lo) * dh;
                assert_eq!(sk, dk[off..off + n], "layer {layer} head {head} K");
                assert_eq!(sv, dv[off..off + n], "layer {layer} head {head} V");
            }
        }
        // a range entirely beyond the valid length is all zeros
        let mut sk = vec![f32::NAN; 2 * dh];
        let mut sv = vec![f32::NAN; 2 * dh];
        kv.stage_rows(0, 0, 20, 22, &mut sk, &mut sv);
        assert!(sk.iter().chain(sv.iter()).all(|&x| x == 0.0));
    }

    #[test]
    fn freeze_and_adopt_share_pages_without_copying() {
        let arena = KvArena::new();
        let mut donor = KvCache::with_arena(arena.clone(), 2, 2, 64, 4);
        let w = 20; // 2 pages per layer (one full, one partial)
        let mut wk = vec![0.0f32; 2 * w * 4];
        for (i, x) in wk.iter_mut().enumerate() {
            *x = i as f32;
        }
        let wv: Vec<f32> = wk.iter().map(|x| -x).collect();
        for layer in 0..2 {
            donor.append_layer(layer, &wk, &wv, w, w, 0).unwrap();
        }
        let before = arena.stats().bytes_in_use;
        let shared = donor.freeze_pages();
        assert_eq!(donor.n_shared_pages(0), 2, "freeze converts in place");
        assert_eq!(arena.stats().bytes_in_use, before, "freeze moves no bytes");

        let mut fork = KvCache::with_arena(arena.clone(), 2, 2, 64, 4);
        fork.adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass).unwrap();
        fork.check_invariants().unwrap();
        assert_eq!(arena.stats().bytes_in_use, before, "adoption charges nothing");
        assert_eq!(fork.lens, donor.lens);
        assert_eq!(fork.positions, donor.positions);
        let (dk, dv) = donor.gather_dense();
        let (fk, fv) = fork.gather_dense();
        assert_eq!(dk, fk);
        assert_eq!(dv, fv);
        assert_ne!(donor.id(), fork.id(), "fork gets a fresh transfer identity");
        assert_eq!(fork.dirty_range(0), Some((0, w)), "adopted slots start dirty");
    }

    #[test]
    fn cow_on_append_preserves_the_donor_rows() {
        let arena = KvArena::new();
        let mut donor = KvCache::with_arena(arena.clone(), 1, 1, 64, 2);
        let w = vec![1.5f32; 20 * 2];
        donor.append_layer(0, &w, &w, 20, 20, 0).unwrap();
        let shared = donor.freeze_pages();
        let mut fork = KvCache::with_arena(arena.clone(), 1, 1, 64, 2);
        fork.adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass).unwrap();
        let before = arena.stats();

        // fork appends into the shared partial tail page -> exactly one CoW
        let one = vec![9.0f32; 2];
        fork.append_layer(0, &one, &one, 1, 1, 20).unwrap();
        let st = arena.stats();
        assert_eq!(st.cow_copies, before.cow_copies + 1, "one page copied on write");
        assert_eq!(
            st.bytes_in_use,
            before.bytes_in_use + Page::bytes(2),
            "CoW charges one private page"
        );
        assert_eq!(fork.row_k(0, 0, 20)[0], 9.0);
        assert_eq!(fork.row_k(0, 0, 19)[0], 1.5, "copied page keeps the prefix rows");
        assert_eq!(donor.lens[0], 20);
        assert_eq!(donor.row_k(0, 0, 19)[0], 1.5, "donor must not see the fork's write");
        assert_eq!(donor.n_shared_pages(0), 2, "donor still reads the frozen pages");
        assert_eq!(fork.n_shared_pages(0), 1, "fork owns only the CoW'd tail page");

        // the donor's own mutation CoWs its side too, independently
        donor.retain_slots(0, &[0, 5, 17]).unwrap();
        let (fk, _) = fork.gather_dense();
        assert_eq!(fk[19 * 2], 1.5, "fork unaffected by donor compaction");
        donor.check_invariants().unwrap();
        fork.check_invariants().unwrap();
    }

    #[test]
    fn sole_reader_mutation_reclaims_without_copy() {
        let arena = KvArena::new();
        let mut kv = KvCache::with_arena(arena.clone(), 1, 1, 64, 2);
        let w = vec![0.5f32; 10 * 2];
        kv.append_layer(0, &w, &w, 10, 10, 0).unwrap();
        let shared = kv.freeze_pages();
        drop(shared); // the tree evicted: the cache is the sole reader
        let before = arena.stats();
        let one = vec![2.0f32; 2];
        kv.append_layer(0, &one, &one, 1, 1, 10).unwrap();
        let st = arena.stats();
        assert_eq!(st.cow_copies, before.cow_copies, "sole reader must not copy");
        assert_eq!(st.bytes_in_use, before.bytes_in_use, "un-sharing is free");
        assert_eq!(kv.n_shared_pages(0), 0, "page reclaimed as owned");
        assert_eq!(kv.row_k(0, 0, 10)[0], 2.0);
    }

    #[test]
    fn adopt_shared_validates_before_installing() {
        let arena = KvArena::new();
        let mut donor = KvCache::with_arena(arena.clone(), 1, 2, 32, 2);
        let w = vec![0.25f32; 2 * 6 * 2];
        donor.append_layer(0, &w, &w, 6, 6, 0).unwrap();
        let shared = donor.freeze_pages();

        // non-empty target
        let mut busy = KvCache::with_arena(arena.clone(), 1, 2, 32, 2);
        busy.append_layer(0, &w, &w, 6, 6, 0).unwrap();
        assert!(busy.adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass).is_err());

        // wrong shape (row width differs)
        let mut narrow = KvCache::with_arena(arena.clone(), 1, 1, 32, 2);
        let err = narrow
            .adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass)
            .unwrap_err();
        assert!(format!("{err}").contains("row-width"), "{err}");
        assert_eq!(narrow.lens[0], 0, "failed adopt leaves the cache untouched");
        assert_eq!(narrow.n_pages(0), 0);

        // page-count / bookkeeping mismatches
        let mut fork = KvCache::with_arena(arena.clone(), 1, 2, 32, 2);
        assert!(fork.adopt_shared(&shared, &[7], &donor.positions, &donor.mass).is_err());
        assert!(fork.adopt_shared(&shared, &donor.lens, &[vec![0]], &donor.mass).is_err());
        fork.adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass).unwrap();
        fork.check_invariants().unwrap();
    }

    #[test]
    fn residency_token_reports_liveness() {
        let kv = filled(1, 1, 16, 2, 3);
        let token = kv.residency_token();
        assert!(token.strong_count() > 0);
        drop(kv);
        assert_eq!(token.strong_count(), 0, "dropped cache must read as dead");
    }

    /// Reference model: plain dense per-layer rows, the old storage layout.
    struct DenseRef {
        h: usize,
        dh: usize,
        rows_k: Vec<Vec<f32>>, // per slot: [H * Dh]
        rows_v: Vec<Vec<f32>>,
        positions: Vec<u64>,
    }

    impl DenseRef {
        fn append(&mut self, win_k: &[f32], win_v: &[f32], w: usize, n_valid: usize, p0: u64) {
            for i in 0..n_valid {
                let mut rk = vec![0.0f32; self.h * self.dh];
                let mut rv = vec![0.0f32; self.h * self.dh];
                for hh in 0..self.h {
                    for d in 0..self.dh {
                        rk[hh * self.dh + d] = win_k[(hh * w + i) * self.dh + d];
                        rv[hh * self.dh + d] = win_v[(hh * w + i) * self.dh + d];
                    }
                }
                self.rows_k.push(rk);
                self.rows_v.push(rv);
                self.positions.push(p0 + i as u64);
            }
        }

        fn retain(&mut self, keep: &[usize]) {
            self.rows_k = keep.iter().map(|&s| self.rows_k[s].clone()).collect();
            self.rows_v = keep.iter().map(|&s| self.rows_v[s].clone()).collect();
            self.positions = keep.iter().map(|&s| self.positions[s]).collect();
        }
    }

    #[derive(Debug)]
    enum Op {
        Append { w: usize, n_valid: usize, seed: u32 },
        Retain { keep_mask_seed: u64 },
    }

    #[test]
    fn paged_store_matches_dense_reference_property() {
        // the head-major arena page layout must be observationally identical
        // to the old dense layout: same gather_dense output, rows, and
        // positions under arbitrary append/retain interleavings
        PropRunner::new(60).run(
            |rng: &mut Xoshiro256| {
                let h = 1 + rng.below(3) as usize;
                let dh = 1 + rng.below(4) as usize;
                let ops: Vec<Op> = (0..10)
                    .map(|_| {
                        if rng.below(3) < 2 {
                            Op::Append {
                                w: 1 + rng.below(9) as usize,
                                n_valid: 0, // filled below
                                seed: rng.below(u32::MAX as u64) as u32,
                            }
                        } else {
                            Op::Retain { keep_mask_seed: rng.below(u64::MAX) }
                        }
                    })
                    .map(|op| match op {
                        Op::Append { w, seed, .. } => {
                            Op::Append { w, n_valid: 1 + (seed as usize) % w, seed }
                        }
                        other => other,
                    })
                    .collect();
                (h, dh, ops)
            },
            |(h, dh, ops)| {
                let (h, dh) = (*h, *dh);
                let c = 96;
                let mut kv = KvCache::with_arena(KvArena::new(), 1, h, c, dh);
                let mut dref = DenseRef {
                    h,
                    dh,
                    rows_k: Vec::new(),
                    rows_v: Vec::new(),
                    positions: Vec::new(),
                };
                let mut next_pos = 0u64;
                for op in ops {
                    match *op {
                        Op::Append { w, n_valid, seed } => {
                            if kv.lens[0] + n_valid > c {
                                continue;
                            }
                            let mut vrng = Xoshiro256::new(seed as u64 + 1);
                            let wk: Vec<f32> =
                                (0..h * w * dh).map(|_| vrng.below(1000) as f32).collect();
                            let wv: Vec<f32> =
                                (0..h * w * dh).map(|_| vrng.below(1000) as f32).collect();
                            kv.append_layer(0, &wk, &wv, w, n_valid, next_pos).unwrap();
                            dref.append(&wk, &wv, w, n_valid, next_pos);
                            next_pos += n_valid as u64;
                        }
                        Op::Retain { keep_mask_seed } => {
                            let n = kv.lens[0];
                            if n == 0 {
                                continue;
                            }
                            let mut krng = Xoshiro256::new(keep_mask_seed);
                            let keep: Vec<usize> =
                                (0..n).filter(|_| krng.below(2) == 0).collect();
                            kv.retain_slots(0, &keep).unwrap();
                            dref.retain(&keep);
                        }
                    }
                    // full observational equivalence after every op
                    prop_assert!(
                        kv.lens[0] == dref.rows_k.len(),
                        "len {} != ref {}",
                        kv.lens[0],
                        dref.rows_k.len()
                    );
                    prop_assert!(kv.positions[0] == dref.positions, "positions diverged");
                    prop_assert!(kv.check_invariants().is_ok(), "invariants broken");
                    let (dk, dv) = kv.gather_dense();
                    for slot in 0..kv.lens[0] {
                        for hh in 0..h {
                            for d in 0..dh {
                                let got_k = dk[(hh * c + slot) * dh + d];
                                let got_v = dv[(hh * c + slot) * dh + d];
                                let want_k = dref.rows_k[slot][hh * dh + d];
                                let want_v = dref.rows_v[slot][hh * dh + d];
                                prop_assert!(
                                    got_k == want_k && got_v == want_v,
                                    "row mismatch at slot {slot} head {hh} d {d}"
                                );
                            }
                        }
                    }
                    // padding beyond lens stays zero
                    for slot in kv.lens[0]..c {
                        for hh in 0..h {
                            for d in 0..dh {
                                prop_assert!(
                                    dk[(hh * c + slot) * dh + d] == 0.0,
                                    "padding not zero at slot {slot}"
                                );
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    // ---- tiered compression (cold-q8) ----

    use crate::runtime::arena::QuantPage;

    /// Append `n` rows of bounded random values (|x| <= 1000) to every layer.
    fn fill_layers(kv: &mut KvCache, n: usize, first_pos: u64, seed: u64) {
        let (l, h, dh) = (kv.l, kv.h, kv.dh);
        let mut rng = Xoshiro256::new(seed);
        for layer in 0..l {
            let wk: Vec<f32> =
                (0..h * n * dh).map(|_| rng.below(2001) as f32 - 1000.0).collect();
            let wv: Vec<f32> =
                (0..h * n * dh).map(|_| rng.below(2001) as f32 - 1000.0).collect();
            kv.append_layer(layer, &wk, &wv, n, n, first_pos).unwrap();
        }
    }

    #[test]
    fn demote_cold_quantizes_only_cold_middle_pages() {
        let arena = KvArena::new();
        let mut kv = KvCache::with_arena(arena.clone(), 1, 2, 128, 4);
        kv.set_quant(true);
        let n = 4 * PAGE_SLOTS; // four full pages, positions 0..64
        fill_layers(&mut kv, n, 0, 42);
        kv.mark_synced();
        let fp32_resident = kv.resident_bytes();
        let (k_ref, v_ref) = kv.gather_dense();

        // cutoff 48: pages 1..=2 are entirely older; page 0 is the sink,
        // page 3 is the hot tail
        let demoted = kv.demote_cold(3 * PAGE_SLOTS as u64);
        assert_eq!(demoted, 2);
        assert_eq!(kv.n_quant_pages(0), 2);
        assert_eq!(
            kv.dirty_range(0),
            Some((PAGE_SLOTS, 3 * PAGE_SLOTS)),
            "each demotion marks exactly its page dirty, once"
        );
        let rw = kv.row_width();
        assert_eq!(kv.resident_bytes(), 2 * Page::bytes(rw) + 2 * QuantPage::bytes_for(rw, 2));
        assert!(kv.resident_bytes() < fp32_resident);
        let st = arena.stats();
        assert_eq!(st.quant_pages, 2);
        assert_eq!(st.quant_bytes, 2 * QuantPage::bytes_for(rw, 2));
        assert!(st.quant_compaction_ratio > 3.0, "ratio {}", st.quant_compaction_ratio);

        // idempotent: a second clean sweep has nothing left to do
        kv.mark_synced();
        assert_eq!(kv.demote_cold(3 * PAGE_SLOTS as u64), 0);

        // sink + tail read back exactly; demoted pages within quant tolerance
        let (kq, vq) = kv.gather_dense();
        let (h, c, dh) = (kv.h, kv.c, kv.dh);
        let tol = 1000.0 / 254.0 + 1e-6;
        for hh in 0..h {
            for slot in 0..n {
                for d in 0..dh {
                    let i = (hh * c + slot) * dh + d;
                    let t = if (PAGE_SLOTS..3 * PAGE_SLOTS).contains(&slot) { tol } else { 0.0 };
                    assert!((kq[i] - k_ref[i]).abs() <= t, "K slot {slot} head {hh} d {d}");
                    assert!((vq[i] - v_ref[i]).abs() <= t, "V slot {slot} head {hh} d {d}");
                }
            }
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn demote_cold_skips_dirty_and_shared_pages() {
        let arena = KvArena::new();
        let mut kv = KvCache::with_arena(arena.clone(), 1, 1, 128, 2);
        kv.set_quant(true);
        fill_layers(&mut kv, 4 * PAGE_SLOTS, 0, 7);
        // never synced: every page overlaps the open dirty range
        assert_eq!(kv.demote_cold(u64::MAX / 2), 0, "dirty pages must not demote");
        kv.mark_synced();
        assert_eq!(kv.demote_cold(u64::MAX / 2), 2, "clean sweep demotes the middle pages");

        // a fork holding only shared (frozen f32) pages demotes nothing
        let mut donor = KvCache::with_arena(arena.clone(), 1, 1, 128, 2);
        fill_layers(&mut donor, 4 * PAGE_SLOTS, 0, 9);
        let shared = donor.freeze_pages(); // donor has quant off: stays f32
        let mut fork = KvCache::with_arena(arena.clone(), 1, 1, 128, 2);
        fork.adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass).unwrap();
        fork.set_quant(true);
        fork.mark_synced();
        assert_eq!(fork.demote_cold(u64::MAX / 2), 0, "shared pages must not demote in place");
        assert_eq!(fork.n_quant_pages(0), 0);
    }

    #[test]
    fn freeze_quantizes_snapshots_and_cow_promotes_on_write() {
        let arena = KvArena::new();
        let mut donor = KvCache::with_arena(arena.clone(), 1, 2, 64, 4);
        donor.set_quant(true);
        let n = 2 * PAGE_SLOTS;
        fill_layers(&mut donor, n, 0, 21);
        donor.mark_synced();
        let (k_ref, _) = donor.gather_dense();
        let before = arena.stats().bytes_in_use;

        let shared = donor.freeze_pages();
        let after = arena.stats().bytes_in_use;
        assert!(after < before / 3, "frozen snapshot must be ~4x smaller: {after} vs {before}");
        assert_eq!(donor.n_quant_pages(0), 2);
        let rw = donor.row_width();
        let snap_bytes: usize = shared.iter().flat_map(|t| t.iter()).map(|sp| sp.bytes()).sum();
        assert_eq!(snap_bytes, 2 * QuantPage::bytes_for(rw, 2));
        assert_eq!(
            donor.dirty_range(0),
            Some((0, n)),
            "re-encoded slots are dirty for the donor's next gather"
        );

        // a fork adopts the Q8 snapshot and reads it within tolerance
        let mut fork = KvCache::with_arena(arena.clone(), 1, 2, 64, 4);
        fork.adopt_shared(&shared, &donor.lens, &donor.positions, &donor.mass).unwrap();
        let (fk, _) = fork.gather_dense();
        let tol = (1000.0 / 254.0 + 1e-6) as f32;
        for (a, b) in fk.iter().zip(k_ref.iter()) {
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }

        // the first write CoWs the shared Q8 page into a private f32 copy —
        // no quantized page is ever written in place
        let cows = arena.stats().cow_copies;
        fork.retain_slots(0, &[0, 5, 9]).unwrap();
        let st = arena.stats();
        assert_eq!(st.cow_copies, cows + 1, "write into a shared Q8 page copies once");
        assert_eq!(fork.n_shared_pages(0), 0);
        assert_eq!(fork.n_quant_pages(0), 0, "the private copy is f32");
        // the moved rows carry (dequantized) values within tolerance
        assert!((fork.row_k(0, 1, 1)[0] - k_ref[(64 + 5) * 4]).abs() <= tol);
        donor.check_invariants().unwrap();
        fork.check_invariants().unwrap();
    }

    #[test]
    fn compaction_promotes_then_redemotes_cold_pages() {
        let arena = KvArena::new();
        let mut kv = KvCache::with_arena(arena.clone(), 1, 1, 128, 2);
        kv.set_quant(true);
        fill_layers(&mut kv, 4 * PAGE_SLOTS, 0, 33);
        kv.mark_synced();
        assert_eq!(kv.demote_cold(3 * PAGE_SLOTS as u64), 2);
        kv.mark_synced();

        // evict one slot from page 1: the move pass promotes the Q8 pages it
        // writes to f32, then re-demotes whatever is still entirely cold.
        // Page 1 now ends at original position 32 (< cutoff 48): re-demoted.
        // Page 2 pulled original position 48 into its last slot: no longer
        // entirely cold, so it stays f32 until the cutoff advances.
        let keep: Vec<usize> = (0..4 * PAGE_SLOTS).filter(|&s| s != PAGE_SLOTS).collect();
        kv.retain_slots(0, &keep).unwrap();
        kv.check_invariants().unwrap();
        assert_eq!(kv.n_quant_pages(0), 1);

        // a higher cutoff re-cools page 2 on the next clean sweep
        kv.mark_synced();
        assert_eq!(kv.demote_cold(4 * PAGE_SLOTS as u64), 1);
        assert_eq!(kv.n_quant_pages(0), 2);
    }

    #[derive(Debug, Clone, Copy)]
    enum QOp {
        Append { n: usize, seed: u64 },
        Retain { seed: u64 },
        Truncate { seed: u64 },
        Demote,
        Freeze,
        Sync,
    }

    #[test]
    fn quantized_store_stays_within_tolerance_property() {
        // a quant-on cache must track a quant-off twin through arbitrary
        // append/compact/evict/freeze/CoW-unshare interleavings within the
        // symmetric-int8 error bound (5% of the per-(layer, head) high-water
        // absmax — each re-quantization cycle contributes at most
        // absmax / 254), with identical lens/positions and exact zero padding
        PropRunner::new(30).run(
            |rng: &mut Xoshiro256| {
                let h = 1 + rng.below(2) as usize;
                let dh = 1 + rng.below(3) as usize;
                let ops: Vec<QOp> = (0..14)
                    .map(|_| match rng.below(8) {
                        0 | 1 | 2 => QOp::Append {
                            n: 1 + rng.below(24) as usize,
                            seed: rng.below(u64::MAX),
                        },
                        3 => QOp::Retain { seed: rng.below(u64::MAX) },
                        4 => QOp::Truncate { seed: rng.below(u64::MAX) },
                        5 | 6 => QOp::Demote,
                        _ => {
                            if rng.below(2) == 0 {
                                QOp::Freeze
                            } else {
                                QOp::Sync
                            }
                        }
                    })
                    .collect();
                (h, dh, ops)
            },
            |(h, dh, ops)| {
                let (h, dh) = (*h, *dh);
                let (l, c) = (2usize, 96usize);
                let mut q = KvCache::with_arena(KvArena::new(), l, h, c, dh);
                q.set_quant(true);
                let mut f = KvCache::with_arena(KvArena::new(), l, h, c, dh);
                let mut next_pos = 0u64;
                let mut frozen: Vec<Vec<Vec<SharedPage>>> = Vec::new();
                // per-(layer, head) high-water absmax of the exact twin: the
                // tolerance reference (a later eviction of the largest values
                // must not retroactively tighten the bound already baked into
                // surviving quantized rows)
                let mut hw = vec![0.0f32; l * h];
                for op in ops {
                    match *op {
                        QOp::Append { n, seed } => {
                            if q.max_len() + n > c {
                                continue;
                            }
                            let mut vrng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let wk: Vec<f32> = (0..h * n * dh)
                                    .map(|_| vrng.below(2001) as f32 - 1000.0)
                                    .collect();
                                let wv: Vec<f32> = (0..h * n * dh)
                                    .map(|_| vrng.below(2001) as f32 - 1000.0)
                                    .collect();
                                q.append_layer(layer, &wk, &wv, n, n, next_pos).unwrap();
                                f.append_layer(layer, &wk, &wv, n, n, next_pos).unwrap();
                            }
                            next_pos += n as u64;
                        }
                        QOp::Retain { seed } => {
                            for layer in 0..l {
                                let mut krng = Xoshiro256::new(seed + layer as u64);
                                let n = q.lens[layer];
                                let keep: Vec<usize> =
                                    (0..n).filter(|_| krng.below(4) > 0).collect();
                                q.retain_slots(layer, &keep).unwrap();
                                f.retain_slots(layer, &keep).unwrap();
                            }
                        }
                        QOp::Truncate { seed } => {
                            let mut trng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let new_len = trng.below(q.lens[layer] as u64 + 1) as usize;
                                q.truncate_layer(layer, new_len).unwrap();
                                f.truncate_layer(layer, new_len).unwrap();
                            }
                        }
                        QOp::Demote => {
                            let cutoff = next_pos.saturating_sub(PAGE_SLOTS as u64);
                            q.demote_cold(cutoff);
                            prop_assert!(
                                f.demote_cold(cutoff) == 0,
                                "quant-off demote must be a no-op"
                            );
                        }
                        QOp::Freeze => {
                            // hold the previous snapshot so mutations exercise
                            // both CoW (still shared) and sole-reader
                            // un-share (handle dropped) on Q8 pages
                            frozen.push(q.freeze_pages());
                            let _ = f.freeze_pages();
                            if frozen.len() > 1 {
                                frozen.remove(0);
                            }
                        }
                        QOp::Sync => {
                            q.mark_synced();
                            f.mark_synced();
                        }
                    }
                    prop_assert!(q.check_invariants().is_ok(), "quant invariants broken");
                    prop_assert!(q.lens == f.lens, "lens diverged");
                    prop_assert!(q.positions == f.positions, "positions diverged");
                    let (qk, qv) = q.gather_dense();
                    let (fk, fv) = f.gather_dense();
                    for layer in 0..l {
                        for hh in 0..h {
                            let base = (layer * h + hh) * c * dh;
                            let row = base..base + c * dh;
                            let absmax = fk[row.clone()]
                                .iter()
                                .chain(fv[row.clone()].iter())
                                .fold(0.0f32, |m, x| m.max(x.abs()));
                            hw[layer * h + hh] = hw[layer * h + hh].max(absmax);
                            let tol = 0.05 * hw[layer * h + hh] + 1e-6;
                            for i in row {
                                prop_assert!(
                                    (qk[i] - fk[i]).abs() <= tol,
                                    "K out of tolerance at {i}: {} vs {} (tol {tol})",
                                    qk[i],
                                    fk[i]
                                );
                                prop_assert!(
                                    (qv[i] - fv[i]).abs() <= tol,
                                    "V out of tolerance at {i}: {} vs {} (tol {tol})",
                                    qv[i],
                                    fv[i]
                                );
                            }
                        }
                    }
                    // padding beyond lens stays exactly zero even in quant mode
                    for layer in 0..l {
                        for hh in 0..h {
                            for slot in q.lens[layer]..c {
                                let i = ((layer * h + hh) * c + slot) * dh;
                                prop_assert!(
                                    qk[i..i + dh].iter().all(|&x| x == 0.0),
                                    "quant padding not zero at slot {slot}"
                                );
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quant_off_is_byte_identical_to_baseline() {
        // `--kv-quant off` must leave the store bit-for-bit as before the
        // quantization feature existed: a cache with the demotion hook wired
        // (but off) checksums identically to one that never touches any
        // quant API, and the arena never sees a Q8 page
        fn fnv1a(data: &[f32]) -> u64 {
            let mut h = 0xcbf29ce484222325u64;
            for x in data {
                for b in x.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
            }
            h
        }
        let arena = KvArena::new();
        let mut hooked = KvCache::with_arena(arena.clone(), 2, 2, 64, 3);
        hooked.set_quant(false); // explicit off (the serving `--kv-quant off` path)
        let mut baseline = KvCache::with_arena(KvArena::new(), 2, 2, 64, 3);
        let mut pos = 0u64;
        for step in 0..6u64 {
            let n = 7 + step as usize;
            let mut vrng = Xoshiro256::new(step * 97 + 5);
            for layer in 0..2 {
                let wk: Vec<f32> =
                    (0..2 * n * 3).map(|_| vrng.below(1000) as f32 * 0.5).collect();
                let wv: Vec<f32> =
                    (0..2 * n * 3).map(|_| vrng.below(1000) as f32 * -0.5).collect();
                hooked.append_layer(layer, &wk, &wv, n, n, pos).unwrap();
                baseline.append_layer(layer, &wk, &wv, n, n, pos).unwrap();
            }
            pos += n as u64;
            // the serving loop's demotion hook: a no-op with quant off
            assert_eq!(hooked.demote_cold(pos), 0);
            hooked.mark_synced();
            let keep: Vec<usize> = (0..hooked.lens[0]).filter(|s| s % 5 != 3).collect();
            hooked.retain_slots(0, &keep).unwrap();
            baseline.retain_slots(0, &keep).unwrap();
        }
        let _ = hooked.freeze_pages(); // freeze with quant off stays f32
        assert_eq!(hooked.n_quant_pages(0), 0);
        let (hk, hv) = hooked.gather_dense();
        let (bk, bv) = baseline.gather_dense();
        assert_eq!(fnv1a(&hk), fnv1a(&bk), "K image diverged with quant off");
        assert_eq!(fnv1a(&hv), fnv1a(&bv), "V image diverged with quant off");
        let st = arena.stats();
        assert_eq!(st.quant_pages, 0, "quant-off arena must never hold a Q8 page");
        assert_eq!(st.quant_bytes, 0);
    }
}
