//! Reusable host-transfer scratch: dense `[L, H, C, Dh]` K/V images kept in
//! sync with their source [`KvCache`] through dirty-range tracking, so the
//! program-call data path is incremental and allocation-free in steady state.
//!
//! Before this layer existed, every `score`/`generate` call allocated two
//! fresh dense buffers and re-copied the entire cache slot-by-slot (O(L·H·C·Dh)
//! per decode step). Now a [`ScratchPool`] owns a small LRU set of
//! [`DenseImage`]s, each stamped with the `(cache id, sync generation)` it
//! was materialized from:
//!
//! - **no-op**: the cache is unchanged since the image was made — upload it
//!   as-is, zero copies;
//! - **incremental**: only the dirty slot ranges are re-copied (appended rows
//!   after a decode step, moved rows after a compaction) and shrunk tails are
//!   zero-filled;
//! - **full**: no image matches (first call, pool eviction, cross-scratch
//!   staleness) — gather everything into a recycled buffer.
//!
//! [`ScratchPool::absorb`] closes the loop on the host-path generate: the
//! device output state the runtime just downloaded *is* the current dense
//! image (resident rows passed through the program unchanged, appended rows
//! were just merged via [`KvCache::replace_from_device`], padding stays
//! zero), so the downloaded buffers become the cache's synced image and the
//! next gather is a no-op.
//!
//! Since the device-residency tier ([`super::device`]) landed, this pool is
//! the SPILL tier: device-resident sequences bypass it entirely, a spilled
//! entry's image is parked here with its stamp ([`ScratchPool::adopt`]) so
//! re-promotion gathers incrementally, and [`ScratchPool::sweep`] releases
//! images of dropped caches so staging bytes track live sequences.
//! Invariants and the bench methodology live in PERF.md.

use std::sync::Weak;
use std::time::Instant;

use super::kv::KvCache;

/// One dense `[L, H, C, Dh]` K/V image, synced to a specific cache state.
pub struct DenseImage {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    cache_id: u64,
    sync_gen: u64,
    /// Liveness of the source cache ([`KvCache::residency_token`]);
    /// [`ScratchPool::sweep`] drops entries whose cache is gone so pooled
    /// staging bytes do not outlive the sequences they cached.
    alive: Weak<()>,
}

/// Cumulative transfer-layer counters (merged into
/// [`super::RuntimeStats`] by the runtime).
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferStats {
    /// Gathers that re-copied the whole image.
    pub gathers_full: u64,
    /// Gathers that re-copied only dirty ranges.
    pub gathers_incremental: u64,
    /// Gathers that copied nothing (image already current).
    pub gathers_noop: u64,
    /// Bytes copied pages→image (K + V, incl. full gathers).
    pub gathered_bytes: u64,
    /// Bytes zero-filled over shrunk regions (K + V).
    pub zeroed_bytes: u64,
    /// Wall-clock seconds spent gathering.
    pub gather_s: f64,
    /// Wall-clock seconds spent dequantizing Q8 pages during gathers
    /// (subset of `gather_s`; zero when `--kv-quant off`).
    pub dequant_s: f64,
    /// Dense-buffer allocations (or regrowths) performed by the pool — zero
    /// in steady state.
    pub dense_allocs: u64,
    /// Device images adopted wholesale via [`ScratchPool::absorb`].
    pub absorbs: u64,
}

/// A bounded LRU pool of [`DenseImage`] scratches, one live entry per cache
/// in the hot set. Entries for dropped caches age out; a cache whose entry
/// was evicted simply pays one full gather.
pub struct ScratchPool {
    /// LRU order: most recently used last.
    entries: Vec<DenseImage>,
    max_entries: usize,
    stats: TransferStats,
}

impl ScratchPool {
    pub fn new(max_entries: usize) -> Self {
        Self {
            entries: Vec::new(),
            max_entries: max_entries.max(1),
            stats: TransferStats::default(),
        }
    }

    pub fn stats(&self) -> TransferStats {
        self.stats
    }

    /// Host bytes currently held by pooled images (K + V). This is staging
    /// memory bounded by `max_entries` full images — exported as
    /// `scratch_resident_bytes` and counted (with the device tier) against
    /// the serving budget by the admission gate.
    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| 4 * (e.k.len() + e.v.len())).sum()
    }

    /// Entries currently held (diagnostics).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Materialize `cache`'s dense image, re-copying as little as possible,
    /// and return it ready for upload. Marks the cache synced.
    pub fn gather(&mut self, cache: &mut KvCache) -> &DenseImage {
        let t0 = Instant::now();
        let n = cache.dense_elems();
        let matched = self.entries.iter().position(|e| {
            e.cache_id == cache.id() && e.sync_gen == cache.sync_gen() && e.k.len() == n
        });
        let idx = match matched {
            Some(i) => {
                if cache.is_clean() {
                    self.stats.gathers_noop += 1;
                } else {
                    let e = &mut self.entries[i];
                    let gb = cache.gather_dirty_into(&mut e.k, &mut e.v);
                    cache.mark_synced();
                    e.sync_gen = cache.sync_gen();
                    self.stats.gathers_incremental += 1;
                    self.stats.gathered_bytes += gb.copied;
                    self.stats.zeroed_bytes += gb.zeroed;
                    self.stats.dequant_s += gb.dequant_ns as f64 * 1e-9;
                }
                i
            }
            None => {
                let i = self.take_slot(cache.id(), n);
                let e = &mut self.entries[i];
                let gb = cache.gather_full_into(&mut e.k, &mut e.v);
                cache.mark_synced();
                e.cache_id = cache.id();
                e.sync_gen = cache.sync_gen();
                e.alive = cache.residency_token();
                self.stats.gathers_full += 1;
                self.stats.gathered_bytes += gb.copied;
                self.stats.zeroed_bytes += gb.zeroed;
                self.stats.dequant_s += gb.dequant_ns as f64 * 1e-9;
                i
            }
        };
        // LRU: move the touched entry to the back
        if idx != self.entries.len() - 1 {
            let e = self.entries.remove(idx);
            self.entries.push(e);
        }
        self.stats.gather_s += t0.elapsed().as_secs_f64();
        self.entries.last().unwrap()
    }

    /// Adopt device-output buffers as `cache`'s current dense image. The
    /// caller guarantees the image equality invariant: the buffers came from
    /// a generate program whose input state was uploaded from this cache's
    /// synced image, and [`KvCache::replace_from_device`] already merged the
    /// appended rows, so buffers == full dense gather of the cache right now
    /// (padding beyond `lens` passes through the program as zeros). On shape
    /// mismatch the buffers are dropped and the cache stays dirty — the next
    /// gather falls back to a full copy, so this is never unsound.
    pub fn absorb(&mut self, cache: &mut KvCache, k: Vec<f32>, v: Vec<f32>) {
        let n = cache.dense_elems();
        if k.len() != n || v.len() != n {
            return;
        }
        cache.mark_synced();
        self.stats.absorbs += 1;
        self.adopt(cache.id(), cache.sync_gen(), cache.residency_token(), k, v);
    }

    /// Install a dense image for a cache WITHOUT access to the cache itself —
    /// the device tier's spill path (the image was read back from a resident
    /// device buffer stamped `(cache_id, sync_gen)`, which is exactly the
    /// dense image of that cache's last sync point). Does not touch dirty
    /// state: if the cache mutated since that stamp, the next gather repairs
    /// the image incrementally via the normal dirty-range path; if the stamp
    /// went stale (another image was synced meanwhile), the next gather falls
    /// back to a full copy — degraded, never corrupt.
    pub fn adopt(
        &mut self,
        cache_id: u64,
        sync_gen: u64,
        alive: Weak<()>,
        k: Vec<f32>,
        v: Vec<f32>,
    ) {
        if let Some(i) = self.entries.iter().position(|e| e.cache_id == cache_id) {
            let e = &mut self.entries[i];
            e.k = k;
            e.v = v;
            e.sync_gen = sync_gen;
            e.alive = alive;
            if i != self.entries.len() - 1 {
                let e = self.entries.remove(i);
                self.entries.push(e);
            }
            return;
        }
        if self.entries.len() >= self.max_entries {
            self.entries.remove(0);
        }
        self.entries.push(DenseImage { k, v, cache_id, sync_gen, alive });
    }

    /// Drop entries whose source cache no longer exists, so pooled staging
    /// bytes (which count against serving admission) do not outlive their
    /// sequences. Called by the runtime alongside the device tier's sweep.
    pub fn sweep(&mut self) {
        self.entries.retain(|e| e.alive.strong_count() > 0);
    }

    /// Drop this cache's entry (deterministic release on engine reset).
    pub fn release(&mut self, cache_id: u64) {
        self.entries.retain(|e| e.cache_id != cache_id);
    }

    /// Pick an entry slot for a full gather: recycle this cache's stale
    /// entry, then grow the pool, then evict the least-recently-used entry
    /// and reuse its buffers.
    fn take_slot(&mut self, cache_id: u64, n: usize) -> usize {
        if let Some(i) = self.entries.iter().position(|e| e.cache_id == cache_id) {
            self.resize_entry(i, n);
            return i;
        }
        if self.entries.len() < self.max_entries {
            self.stats.dense_allocs += 1;
            self.entries.push(DenseImage {
                k: vec![0.0; n],
                v: vec![0.0; n],
                cache_id,
                sync_gen: 0,
                alive: Weak::new(),
            });
            return self.entries.len() - 1;
        }
        self.resize_entry(0, n);
        0
    }

    fn resize_entry(&mut self, i: usize, n: usize) {
        let e = &mut self.entries[i];
        if e.k.capacity() < n || e.v.capacity() < n {
            self.stats.dense_allocs += 1;
        }
        e.k.resize(n, 0.0);
        e.v.resize(n, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::runtime::arena::KvArena;
    use crate::util::prop::PropRunner;
    use crate::util::rng::Xoshiro256;

    fn mk_cache(l: usize, h: usize, c: usize, dh: usize) -> KvCache {
        KvCache::with_arena(KvArena::new(), l, h, c, dh)
    }

    fn append_random(kv: &mut KvCache, n: usize, next_pos: &mut u64, rng: &mut Xoshiro256) {
        let (l, h, dh) = (kv.l, kv.h, kv.dh);
        for layer in 0..l {
            let wk: Vec<f32> = (0..h * n * dh).map(|_| rng.below(1000) as f32 * 0.5).collect();
            let wv: Vec<f32> = (0..h * n * dh).map(|_| rng.below(1000) as f32 * -0.5).collect();
            kv.append_layer(layer, &wk, &wv, n, n, *next_pos).unwrap();
        }
        *next_pos += n as u64;
    }

    /// The image the pool holds must equal a from-scratch dense gather.
    fn assert_image_current(pool: &mut ScratchPool, kv: &mut KvCache) -> Result<(), String> {
        let (fk, fv) = kv.gather_dense();
        let img = pool.gather(kv);
        prop_assert!(img.k == fk, "K image diverged from full gather");
        prop_assert!(img.v == fv, "V image diverged from full gather");
        Ok(())
    }

    #[test]
    fn second_gather_of_unchanged_cache_is_noop() {
        let mut kv = mk_cache(2, 2, 32, 4);
        let mut pos = 0;
        let mut rng = Xoshiro256::new(7);
        append_random(&mut kv, 10, &mut pos, &mut rng);
        let mut pool = ScratchPool::new(2);
        pool.gather(&mut kv);
        assert_eq!(pool.stats().gathers_full, 1);
        pool.gather(&mut kv);
        let st = pool.stats();
        assert_eq!(st.gathers_noop, 1);
        assert_eq!(st.gathers_full, 1);
    }

    #[test]
    fn append_only_step_gathers_only_appended_rows() {
        let (l, h, c, dh) = (3usize, 2usize, 64usize, 4usize);
        let mut kv = mk_cache(l, h, c, dh);
        let mut pos = 0;
        let mut rng = Xoshiro256::new(11);
        append_random(&mut kv, 20, &mut pos, &mut rng);
        let mut pool = ScratchPool::new(2);
        pool.gather(&mut kv);
        let before = pool.stats();

        // one decode-like step: a single appended row per layer
        append_random(&mut kv, 1, &mut pos, &mut rng);
        pool.gather(&mut kv);
        let st = pool.stats();
        assert_eq!(st.gathers_incremental, before.gathers_incremental + 1);
        let row_bytes = (2 * 4 * l * h * dh) as u64; // K+V, f32, one slot/layer
        assert_eq!(st.gathered_bytes - before.gathered_bytes, row_bytes);
        assert_eq!(st.zeroed_bytes, before.zeroed_bytes);
        assert_eq!(st.dense_allocs, before.dense_allocs, "steady state must not allocate");
    }

    #[test]
    fn absorb_makes_next_gather_noop() {
        let (l, h, c, dh) = (2usize, 2usize, 16usize, 3usize);
        let mut kv = mk_cache(l, h, c, dh);
        let mut pos = 0;
        let mut rng = Xoshiro256::new(13);
        append_random(&mut kv, 5, &mut pos, &mut rng);
        let mut pool = ScratchPool::new(2);
        let (mut dk, mut dv) = {
            let img = pool.gather(&mut kv);
            (img.k.clone(), img.v.clone())
        };
        // simulate the device appending one slot per layer
        let lens: Vec<i32> = kv.lens.iter().map(|&x| x as i32 + 1).collect();
        for layer in 0..l {
            let slot = kv.lens[layer];
            for hh in 0..h {
                let off = ((layer * h + hh) * c + slot) * dh;
                for d in 0..dh {
                    dk[off + d] = 9.0 + d as f32;
                    dv[off + d] = -(9.0 + d as f32);
                }
            }
        }
        kv.replace_from_device(&dk, &dv, &lens, 1, pos).unwrap();
        pool.absorb(&mut kv, dk, dv);
        assert!(kv.is_clean());
        let before = pool.stats();
        {
            let img = pool.gather(&mut kv);
            let (fk, fv) = kv.gather_dense();
            assert_eq!(img.k, fk);
            assert_eq!(img.v, fv);
        }
        let st = pool.stats();
        assert_eq!(st.gathers_noop, before.gathers_noop + 1);
        assert_eq!(st.gathered_bytes, before.gathered_bytes);
    }

    #[test]
    fn pool_eviction_falls_back_to_full_gather() {
        // pool of 1: two caches alternating must thrash (full gathers) but
        // never leak one cache's rows into the other's image
        let mut a = mk_cache(1, 1, 16, 2);
        let mut b = mk_cache(1, 1, 16, 2);
        let mut pos_a = 0;
        let mut pos_b = 0;
        let mut rng = Xoshiro256::new(17);
        append_random(&mut a, 4, &mut pos_a, &mut rng);
        append_random(&mut b, 9, &mut pos_b, &mut rng);
        let mut pool = ScratchPool::new(1);
        for _ in 0..3 {
            {
                let (fk, _) = a.gather_dense();
                let img = pool.gather(&mut a);
                assert_eq!(img.k, fk, "cache A image corrupted by scratch reuse");
            }
            {
                let (fk, _) = b.gather_dense();
                let img = pool.gather(&mut b);
                assert_eq!(img.k, fk, "cache B image corrupted by scratch reuse");
            }
        }
        let st = pool.stats();
        assert_eq!(st.gathers_full, 6);
        assert_eq!(st.gathers_noop, 0);
        assert!(st.dense_allocs <= 2, "evictions must recycle buffers, not allocate");
    }

    #[test]
    fn sweep_drops_entries_of_dead_caches() {
        let mut pool = ScratchPool::new(4);
        let mut a = mk_cache(1, 1, 16, 2);
        let mut b = mk_cache(1, 1, 16, 2);
        let mut rng = Xoshiro256::new(23);
        let (mut pa, mut pb) = (0, 0);
        append_random(&mut a, 3, &mut pa, &mut rng);
        append_random(&mut b, 5, &mut pb, &mut rng);
        pool.gather(&mut a);
        pool.gather(&mut b);
        assert_eq!(pool.len(), 2);
        let bytes_both = pool.resident_bytes();
        drop(a);
        pool.sweep();
        assert_eq!(pool.len(), 1, "dead cache's image must be swept");
        assert!(pool.resident_bytes() < bytes_both);
        // the survivor still serves incremental gathers
        let before = pool.stats();
        pool.gather(&mut b);
        assert_eq!(pool.stats().gathers_noop, before.gathers_noop + 1);
    }

    #[test]
    fn adopt_installs_an_incrementally_valid_image() {
        // adopt (the device tier's spill path) hands the pool an image with
        // an explicit stamp; a next gather with a matching stamp is a no-op,
        // and pending dirty ranges repair it incrementally
        let mut kv = mk_cache(2, 1, 32, 2);
        let mut pos = 0;
        let mut rng = Xoshiro256::new(29);
        append_random(&mut kv, 6, &mut pos, &mut rng);
        let (fk, fv) = kv.gather_dense();
        kv.mark_synced();
        let mut pool = ScratchPool::new(2);
        pool.adopt(kv.id(), kv.sync_gen(), kv.residency_token(), fk, fv);
        let before = pool.stats();
        assert_image_current(&mut pool, &mut kv).unwrap();
        assert_eq!(pool.stats().gathers_noop, before.gathers_noop + 1);
        // mutate after the adopt stamp: the image repairs incrementally
        append_random(&mut kv, 2, &mut pos, &mut rng);
        assert_image_current(&mut pool, &mut kv).unwrap();
        let st = pool.stats();
        assert_eq!(st.gathers_incremental, before.gathers_incremental + 1);
        assert_eq!(st.gathers_full, before.gathers_full, "adopted image must avoid full gathers");
    }

    #[derive(Debug, Clone, Copy)]
    enum Op {
        Append { n: usize },
        Retain { seed: u64 },
        Truncate { seed: u64 },
        DeviceStep { absorb: bool },
    }

    #[test]
    fn incremental_gather_matches_full_gather_property() {
        // random append/evict/truncate/device-merge sequences over two caches
        // sharing one pool: the incrementally-maintained image must stay
        // byte-identical to a from-scratch full gather after every op,
        // including zero-fill of shrunk regions and no stale-row leaks when
        // the scratch is reused across caches
        PropRunner::new(40).run(
            |rng: &mut Xoshiro256| {
                let h = 1 + rng.below(3) as usize;
                let dh = 1 + rng.below(3) as usize;
                let pool_cap = 1 + rng.below(2) as usize; // 1 forces reuse
                let ops: Vec<(usize, Op)> = (0..14)
                    .map(|_| {
                        let which = rng.below(2) as usize;
                        let op = match rng.below(5) {
                            0 | 1 => Op::Append { n: 1 + rng.below(6) as usize },
                            2 => Op::Retain { seed: rng.below(u64::MAX) },
                            3 => Op::Truncate { seed: rng.below(u64::MAX) },
                            _ => Op::DeviceStep { absorb: rng.below(2) == 0 },
                        };
                        (which, op)
                    })
                    .collect();
                (h, dh, pool_cap, ops)
            },
            |(h, dh, pool_cap, ops)| {
                let (h, dh) = (*h, *dh);
                let c = 48;
                let l = 2;
                let mut caches = [mk_cache(l, h, c, dh), mk_cache(l, h, c, dh)];
                let mut next_pos = [0u64, 0u64];
                let mut pool = ScratchPool::new(*pool_cap);
                let mut rng = Xoshiro256::new(0xd1f7);
                for &(which, op) in ops {
                    let kv = &mut caches[which];
                    match op {
                        Op::Append { n } => {
                            if kv.max_len() + n > c {
                                continue;
                            }
                            append_random(kv, n, &mut next_pos[which], &mut rng);
                        }
                        Op::Retain { seed } => {
                            let mut krng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let n = kv.lens[layer];
                                let keep: Vec<usize> =
                                    (0..n).filter(|_| krng.below(3) > 0).collect();
                                kv.retain_slots(layer, &keep).unwrap();
                            }
                        }
                        Op::Truncate { seed } => {
                            let mut trng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let n = kv.lens[layer];
                                let new_len = trng.below(n as u64 + 1) as usize;
                                kv.truncate_layer(layer, new_len).unwrap();
                            }
                        }
                        Op::DeviceStep { absorb } => {
                            // simulate a generate call: upload the gathered
                            // image, device appends one slot per layer
                            if kv.max_len() + 1 > c {
                                continue;
                            }
                            let (mut dk, mut dv) = {
                                let img = pool.gather(kv);
                                (img.k.clone(), img.v.clone())
                            };
                            let lens: Vec<i32> =
                                kv.lens.iter().map(|&x| x as i32 + 1).collect();
                            for layer in 0..l {
                                let slot = kv.lens[layer];
                                for hh in 0..h {
                                    let off = ((layer * h + hh) * c + slot) * dh;
                                    for d in 0..dh {
                                        dk[off + d] = rng.below(1000) as f32 * 0.25;
                                        dv[off + d] = rng.below(1000) as f32 * -0.25;
                                    }
                                }
                            }
                            kv.replace_from_device(&dk, &dv, &lens, 1, next_pos[which])
                                .unwrap();
                            next_pos[which] += 1;
                            if absorb {
                                pool.absorb(kv, dk, dv);
                            }
                        }
                    }
                    prop_assert!(kv.check_invariants().is_ok(), "invariants broken");
                    assert_image_current(&mut pool, &mut caches[which])?;
                    // the *other* cache's image must also still be consistent
                    // (catches stale-row leaks through shared scratch slots)
                    assert_image_current(&mut pool, &mut caches[1 - which])?;
                }
                Ok(())
            },
        );
    }
}
