//! Device-call error taxonomy and poison-safe locking.
//!
//! Every device-call path (`score` / `generate` / upload / download) used to
//! surface bare `anyhow` strings, so callers could not tell a blip worth
//! retrying from a lost device or a real bug. [`CallError`] classifies a
//! failure into four kinds with stable wire codes; it is carried *inside*
//! `anyhow::Error` (it implements `std::error::Error`), so the existing
//! `Result<T>` plumbing is unchanged and [`classify`] recovers the kind by
//! downcast, falling back to marker-string matching for errors raised below
//! the taxonomy (arena OOM, stub unavailability, injected faults).
//!
//! The recovery contract that makes retry sound lives one level up (see
//! PERF.md "Failure handling & recovery"): a failed call mutates nothing
//! durable — host arena pages are the source of truth, so dropping the
//! sequence's residency entry and re-gathering rebuilds the exact pre-call
//! image, even after a failed *donated* generate consumed the resident
//! buffers.
//!
//! [`lock_recover`] is the companion for panic isolation: a panicked call on
//! the worker pool must not cascade-poison every runtime mutex into
//! process-wide unwrap aborts. It clears the poison (the guarded state is
//! counters/caches with per-entry invariants, never mid-transaction), logs
//! once, and bumps a process-wide `lock_poisoned` counter exported via
//! `op:stats`.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// What a failed device call means for the caller's next move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CallErrorKind {
    /// A blip (injected fault, spurious transfer failure): retry the call
    /// after rebuilding from arena pages.
    Transient,
    /// The device (or its runtime) went away; the call may succeed on a
    /// fresh acquire, so it is retryable, but repeated losses flip the tier
    /// into degraded mode.
    DeviceLost,
    /// Out of memory (arena budget, device allocation): retrying the same
    /// call cannot succeed until pressure drops — not retryable here; the
    /// scheduler's admission gate is the pressure valve.
    Oom,
    /// Anything else: bugs, unavailable backend, panics. Never retried.
    Fatal,
}

impl CallErrorKind {
    /// Stable wire code, used in protocol error responses and bench JSON.
    pub fn code(self) -> &'static str {
        match self {
            CallErrorKind::Transient => "transient",
            CallErrorKind::DeviceLost => "device-lost",
            CallErrorKind::Oom => "oom",
            CallErrorKind::Fatal => "fatal",
        }
    }

    /// Whether a rebuild-from-arena retry can help.
    pub fn retryable(self) -> bool {
        matches!(self, CallErrorKind::Transient | CallErrorKind::DeviceLost)
    }
}

/// A classified device-call failure, carried inside `anyhow::Error`.
#[derive(Debug)]
pub struct CallError {
    pub kind: CallErrorKind,
    pub msg: String,
}

impl fmt::Display for CallError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.kind.code(), self.msg)
    }
}

impl std::error::Error for CallError {}

impl CallError {
    pub fn new(kind: CallErrorKind, msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(CallError { kind, msg: msg.into() })
    }

    pub fn transient(msg: impl Into<String>) -> anyhow::Error {
        Self::new(CallErrorKind::Transient, msg)
    }

    pub fn device_lost(msg: impl Into<String>) -> anyhow::Error {
        Self::new(CallErrorKind::DeviceLost, msg)
    }

    pub fn oom(msg: impl Into<String>) -> anyhow::Error {
        Self::new(CallErrorKind::Oom, msg)
    }

    pub fn fatal(msg: impl Into<String>) -> anyhow::Error {
        Self::new(CallErrorKind::Fatal, msg)
    }

    /// Re-wrap an arbitrary error with an explicit kind, preserving its
    /// rendered message (the original chain is flattened — classification
    /// only needs the kind and a human-readable cause).
    pub fn wrap(kind: CallErrorKind, err: &anyhow::Error) -> anyhow::Error {
        Self::new(kind, format!("{err:#}"))
    }
}

/// Classify an error from a device-call path. Typed [`CallError`]s anywhere
/// in the chain win; otherwise marker strings decide. Unknown errors are
/// `Fatal`: retrying an unclassified failure risks re-executing a bug with
/// side effects, so the default is quarantine, not optimism.
pub fn classify(err: &anyhow::Error) -> CallErrorKind {
    for cause in err.chain() {
        if let Some(ce) = cause.downcast_ref::<CallError>() {
            return ce.kind;
        }
    }
    classify_msg(&format!("{err:#}"))
}

/// Marker-string fallback for errors raised below the taxonomy. The OOM
/// markers are `runtime::arena::ARENA_OOM_MARKER` ("kv-arena-OOM") and the
/// engine's simulated-memory marker ("simulated-OOM") — both contain "OOM",
/// matched case-sensitively to avoid catching e.g. "zoom".
pub fn classify_msg(msg: &str) -> CallErrorKind {
    if msg.contains(xla::fault::TRANSIENT_MARKER) {
        CallErrorKind::Transient
    } else if msg.contains("DEVICE_LOST") || msg.contains("device lost") {
        CallErrorKind::DeviceLost
    } else if msg.contains("OOM") || msg.contains("RESOURCE_EXHAUSTED") || msg.contains("out of memory")
    {
        CallErrorKind::Oom
    } else {
        // Includes xla::fault::FATAL_MARKER, worker panics, and the stub's
        // "backend unavailable" — the stub can never execute, so retrying
        // there would only burn the retry budget.
        CallErrorKind::Fatal
    }
}

static LOCK_POISONED: AtomicU64 = AtomicU64::new(0);
static POISON_LOGGED: AtomicBool = AtomicBool::new(false);

/// Lock a mutex, recovering from poison instead of panicking. Poison means
/// some thread panicked while holding the guard; every runtime mutex guards
/// state with per-entry invariants (stat counters, LRU caches, staging
/// buffers) that a mid-panic writer cannot half-update into inconsistency,
/// so recovery is taking the data as-is. Clears the poison flag (one panic,
/// one count), logs the first occurrence, and bumps the process-wide
/// [`lock_poisoned_total`] stat.
pub fn lock_recover<'a, T>(m: &'a Mutex<T>, what: &str) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            LOCK_POISONED.fetch_add(1, Ordering::Relaxed);
            if !POISON_LOGGED.swap(true, Ordering::Relaxed) {
                eprintln!("lacache: recovered poisoned mutex ({what}); suppressing further logs");
            }
            poisoned.into_inner()
        }
    }
}

/// Total poisoned-mutex recoveries since process start (exported via
/// `op:stats` as `lock_poisoned`).
pub fn lock_poisoned_total() -> u64 {
    LOCK_POISONED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_carry_codes_and_retryability() {
        assert_eq!(CallErrorKind::Transient.code(), "transient");
        assert_eq!(CallErrorKind::DeviceLost.code(), "device-lost");
        assert_eq!(CallErrorKind::Oom.code(), "oom");
        assert_eq!(CallErrorKind::Fatal.code(), "fatal");
        assert!(CallErrorKind::Transient.retryable());
        assert!(CallErrorKind::DeviceLost.retryable());
        assert!(!CallErrorKind::Oom.retryable());
        assert!(!CallErrorKind::Fatal.retryable());
    }

    #[test]
    fn classify_prefers_typed_errors_over_markers() {
        // a typed Transient whose message *mentions* OOM still classifies
        // as Transient: the downcast wins over string matching
        let e = CallError::transient("spurious OOM-looking blip");
        assert_eq!(classify(&e), CallErrorKind::Transient);
        // and the type survives context wrapping
        let e = e.context("while scoring window 3");
        assert_eq!(classify(&e), CallErrorKind::Transient);
    }

    #[test]
    fn classify_falls_back_to_marker_strings() {
        assert_eq!(classify(&anyhow::anyhow!("kv-arena-OOM: budget")), CallErrorKind::Oom);
        assert_eq!(classify(&anyhow::anyhow!("simulated-OOM at step 4")), CallErrorKind::Oom);
        assert_eq!(
            classify(&anyhow::anyhow!("pjrt: RESOURCE_EXHAUSTED alloc")),
            CallErrorKind::Oom
        );
        assert_eq!(classify(&anyhow::anyhow!("pjrt: DEVICE_LOST")), CallErrorKind::DeviceLost);
        assert_eq!(
            classify(&anyhow::anyhow!("{} at upload", xla::fault::TRANSIENT_MARKER)),
            CallErrorKind::Transient
        );
        assert_eq!(
            classify(&anyhow::anyhow!("{} at execute", xla::fault::FATAL_MARKER)),
            CallErrorKind::Fatal
        );
        // the stub's unavailable error must never be retried
        assert_eq!(
            classify(&anyhow::anyhow!(
                "xla backend unavailable (stub build: native PJRT bindings are not linked)"
            )),
            CallErrorKind::Fatal
        );
        assert_eq!(classify(&anyhow::anyhow!("some novel failure")), CallErrorKind::Fatal);
    }

    #[test]
    fn lock_recover_clears_poison_and_counts() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let before = lock_poisoned_total();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        {
            let mut g = lock_recover(&m, "test mutex");
            assert_eq!(*g, 7);
            *g = 8;
        }
        assert!(!m.is_poisoned(), "lock_recover must clear the poison flag");
        assert_eq!(lock_poisoned_total(), before + 1);
        // subsequent locks are clean and do not re-count
        assert_eq!(*lock_recover(&m, "test mutex"), 8);
        assert_eq!(lock_poisoned_total(), before + 1);
    }
}
