//! In-flight call executor: a small scoped worker pool that runs device
//! calls off the reactor thread so one long prefill chunk never stalls the
//! decode fleet (split-phase submit/reap scheduling, PERF.md "Async
//! overlap").
//!
//! The pool is built over [`std::thread::scope`], so jobs may borrow from
//! the environment (`&Runtime`, arena handles) — no `'static` laundering.
//! Each job OWNS the sequence state it advances (the scheduler moves the
//! whole sequence into the closure and gets it back in the
//! [`Completion`]), which is what keeps `DeviceTier` accounting race-free:
//! a sequence's resident image is only ever touched by the single in-flight
//! call that owns that sequence.
//!
//! Shutdown is by drop: dropping the executor closes the job channel, each
//! worker drains its current job and exits, and the enclosing scope joins
//! them. Completions of jobs still running at drop are discarded.
//!
//! **Panic isolation**: jobs run under [`std::panic::catch_unwind`], so a
//! panicking device call surfaces as `Completion { out: Err(panic message) }`
//! instead of tearing down `std::thread::scope` (which would abort the whole
//! serving loop). The worker thread itself survives — the pool never loses
//! capacity to a job panic — and whatever the job owned (the sequence state)
//! was dropped during unwind, returning its arena pages. The scheduler turns
//! such completions into a structured `Fatal` error for just that sequence.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use super::error::lock_recover;

/// A completed in-flight call: the ticket it was submitted under plus the
/// job's output (which carries the sequence state back to the scheduler),
/// or the panic message if the job panicked (the sequence it owned was
/// dropped during unwind).
pub struct Completion<T> {
    pub ticket: u64,
    pub out: Result<T, String>,
}

/// Render a panic payload (the `Box<dyn Any>` from `catch_unwind`) as text.
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

type Job<'env, T> = Box<dyn FnOnce() -> T + Send + 'env>;

/// Worker pool for in-flight device calls. `'env` is the borrow scope the
/// jobs may capture (the serving loop's `thread::scope` environment).
pub struct CallExecutor<'env, T: Send + 'env> {
    tx: Sender<(u64, Job<'env, T>)>,
    done_tx: Sender<Completion<T>>,
    done_rx: Receiver<Completion<T>>,
    workers: usize,
    inflight: usize,
}

impl<'env, T: Send + 'env> CallExecutor<'env, T> {
    /// Spawn `workers` (min 1) pool threads on `scope`. The executor must be
    /// dropped before the scope closes (drop closes the job channel, which
    /// is what lets the scope's implicit join finish).
    pub fn new<'scope>(scope: &'scope thread::Scope<'scope, 'env>, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<(u64, Job<'env, T>)>();
        let rx = Arc::new(Mutex::new(rx));
        let (done_tx, done_rx) = channel::<Completion<T>>();
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let done_tx = done_tx.clone();
            scope.spawn(move || loop {
                // hold the receiver lock only while waiting, never while
                // running a job, so idle workers hand off cleanly
                let msg = lock_recover(&rx, "executor job queue").recv();
                match msg {
                    Ok((ticket, job)) => {
                        // catch_unwind: a panicking job must cost one
                        // sequence, not the scope (and not this worker)
                        let out = std::panic::catch_unwind(AssertUnwindSafe(job))
                            .map_err(panic_msg);
                        if done_tx.send(Completion { ticket, out }).is_err() {
                            return; // executor dropped mid-job
                        }
                    }
                    Err(_) => return, // job channel closed: shutdown
                }
            });
        }
        CallExecutor { tx, done_tx, done_rx, workers, inflight: 0 }
    }

    /// Hand a job to the pool. Returns immediately; the result comes back
    /// through [`Self::reap`] under `ticket`. Workers survive job panics,
    /// so the pool is always reachable; if the channel is somehow down
    /// anyway, the job runs inline rather than being lost (or aborting the
    /// serving loop, as the old `expect` here did).
    pub fn submit(&mut self, ticket: u64, job: impl FnOnce() -> T + Send + 'env) {
        self.inflight += 1;
        if let Err(std::sync::mpsc::SendError((ticket, job))) =
            self.tx.send((ticket, Box::new(job)))
        {
            let out = std::panic::catch_unwind(AssertUnwindSafe(job)).map_err(panic_msg);
            let _ = self.done_tx.send(Completion { ticket, out });
        }
    }

    /// Drain completions. With `wait` set (and calls in flight), blocks up
    /// to that long for the first completion; either way every completion
    /// already queued is drained without blocking.
    pub fn reap(&mut self, wait: Option<Duration>) -> Vec<Completion<T>> {
        let mut done = Vec::new();
        if let Some(d) = wait {
            if self.inflight > 0 {
                match self.done_rx.recv_timeout(d) {
                    Ok(c) => done.push(c),
                    Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => {}
                }
            }
        }
        while let Ok(c) = self.done_rx.try_recv() {
            done.push(c);
        }
        self.inflight -= done.len();
        done
    }

    /// Spawn `lanes` independent pools of `workers_per_lane` threads each —
    /// one lane per device shard, so per-shard call queues drain in
    /// parallel and a stalled device only backs up its own lane. Lanes
    /// share nothing (each has its own job and completion channels); the
    /// caller routes submits by shard and drains every lane at reap.
    pub fn lanes<'scope>(
        scope: &'scope thread::Scope<'scope, 'env>,
        lanes: usize,
        workers_per_lane: usize,
    ) -> Vec<Self> {
        (0..lanes.max(1)).map(|_| Self::new(scope, workers_per_lane)).collect()
    }

    /// Jobs submitted but not yet reaped.
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Pool size (the in-flight concurrency bound).
    pub fn workers(&self) -> usize {
        self.workers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_complete_and_carry_tickets() {
        thread::scope(|s| {
            let mut ex: CallExecutor<'_, u64> = CallExecutor::new(s, 4);
            for t in 0..16u64 {
                ex.submit(t, move || t * 10);
            }
            let mut got: Vec<Completion<u64>> = Vec::new();
            while got.len() < 16 {
                got.extend(ex.reap(Some(Duration::from_millis(200))));
            }
            assert_eq!(ex.inflight(), 0);
            got.sort_by_key(|c| c.ticket);
            for (i, c) in got.iter().enumerate() {
                assert_eq!(c.ticket, i as u64);
                assert_eq!(c.out, Ok(i as u64 * 10));
            }
        });
    }

    #[test]
    fn jobs_borrow_from_the_environment() {
        let data: Vec<u64> = (0..100).collect();
        let want: u64 = data.iter().sum();
        thread::scope(|s| {
            let mut ex = CallExecutor::new(s, 2);
            ex.submit(7, || data.iter().sum::<u64>());
            let done = loop {
                let mut d = ex.reap(Some(Duration::from_millis(500)));
                if !d.is_empty() {
                    break d.remove(0);
                }
            };
            assert_eq!(done.ticket, 7);
            assert_eq!(done.out, Ok(want));
        });
    }

    #[test]
    fn reap_without_wait_does_not_block() {
        thread::scope(|s| {
            let mut ex: CallExecutor<'_, ()> = CallExecutor::new(s, 1);
            assert!(ex.reap(None).is_empty());
            ex.submit(1, || thread::sleep(Duration::from_millis(20)));
            let mut done = ex.reap(None); // may legitimately see nothing yet
            while done.is_empty() {
                done = ex.reap(Some(Duration::from_millis(200)));
            }
            assert_eq!(done[0].ticket, 1);
            assert_eq!(ex.inflight(), 0);
        });
    }

    #[test]
    fn slow_job_does_not_block_fast_jobs() {
        thread::scope(|s| {
            let mut ex: CallExecutor<'_, &'static str> = CallExecutor::new(s, 2);
            ex.submit(1, || {
                thread::sleep(Duration::from_millis(200));
                "slow"
            });
            ex.submit(2, || "fast");
            let first = loop {
                let mut d = ex.reap(Some(Duration::from_millis(1000)));
                if !d.is_empty() {
                    break d.remove(0);
                }
            };
            assert_eq!(first.ticket, 2, "fast job reaps while slow is in flight");
            while ex.inflight() > 0 {
                ex.reap(Some(Duration::from_millis(1000)));
            }
        });
    }

    #[test]
    fn clamps_to_at_least_one_worker() {
        thread::scope(|s| {
            let mut ex: CallExecutor<'_, i32> = CallExecutor::new(s, 0);
            assert_eq!(ex.workers(), 1);
            ex.submit(0, || 42);
            let mut d = Vec::new();
            while d.is_empty() {
                d = ex.reap(Some(Duration::from_millis(200)));
            }
            assert_eq!(d[0].out, Ok(42));
        });
    }

    #[test]
    fn lanes_are_independent_pools() {
        thread::scope(|s| {
            let mut lanes: Vec<CallExecutor<'_, usize>> = CallExecutor::lanes(s, 3, 2);
            assert_eq!(lanes.len(), 3);
            // a slow job on lane 0 does not delay lane 2's completion
            lanes[0].submit(0, || {
                thread::sleep(Duration::from_millis(150));
                0
            });
            lanes[2].submit(2, || 2);
            let fast = loop {
                let mut d = lanes[2].reap(Some(Duration::from_millis(1000)));
                if !d.is_empty() {
                    break d.remove(0);
                }
            };
            assert_eq!(fast.out, Ok(2));
            assert_eq!(lanes[0].inflight(), 1, "lane 0's job is still in flight");
            while lanes[0].inflight() > 0 {
                lanes[0].reap(Some(Duration::from_millis(1000)));
            }
            // zero lanes clamps to one, like the worker count
            let extra: Vec<CallExecutor<'_, ()>> = CallExecutor::lanes(s, 0, 1);
            assert_eq!(extra.len(), 1);
        });
    }

    #[test]
    fn panicking_job_is_isolated_and_pool_survives() {
        thread::scope(|s| {
            // one worker: if the panic killed it, the second job could
            // never complete and the reap loop below would spin forever
            let mut ex: CallExecutor<'_, u32> = CallExecutor::new(s, 1);
            ex.submit(1, || panic!("injected panic mid-call"));
            ex.submit(2, || 5);
            let mut got: Vec<Completion<u32>> = Vec::new();
            while got.len() < 2 {
                got.extend(ex.reap(Some(Duration::from_millis(500))));
            }
            got.sort_by_key(|c| c.ticket);
            let err = got[0].out.as_ref().unwrap_err();
            assert!(err.contains("injected panic"), "panic message must surface, got {err:?}");
            assert_eq!(got[1].out, Ok(5), "the worker survives the panicked job");
            assert_eq!(ex.inflight(), 0);
        });
    }
}
