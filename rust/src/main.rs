//! `lacache-serve` — the serving launcher (leader entrypoint).
//!
//! ```text
//! lacache-serve --model base --policy lacache:budget=128 --listen 127.0.0.1:7333
//! lacache-serve --config serve.json
//! ```
//!
//! Speaks a JSON-lines protocol over TCP (see `server::protocol`); clients
//! send `{"op":"generate","id":1,"prompt":"<mark> w4 w5 <sep> ...","max_new_tokens":8}`
//! and receive one JSON reply line per request. `op:stats` exposes the
//! metrics registry; `op:shutdown` drains and exits.

use anyhow::Result;

use lacache::config::ServeConfig;
use lacache::server::run_server;
use lacache::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env()
        .describe("config", "JSON config file", None)
        .describe("model", "model name from artifacts/manifest.json", Some("base"))
        .describe("policy", "cache policy spec, e.g. lacache:budget=128,span=2", Some("lacache:budget=128"))
        .describe("listen", "TCP listen address", Some("127.0.0.1:7333"))
        .describe("window", "prompt ingestion window", Some("128"))
        .describe("capacity", "compiled cache capacity C", Some("256"))
        .describe("max-new-tokens", "per-request generation cap", Some("256"))
        .describe("max-queue", "admission-control queue bound", Some("64"))
        .describe("decode-quantum", "decode steps per scheduling round", Some("16"))
        .describe("max-active", "max concurrently active sequences", Some("4"))
        .describe("kv-pool-bytes", "paged-KV arena byte budget (0 = unlimited)", Some("0"))
        .describe("scratch-pool-entries", "warm dense host scratch images (LRU)", Some("16"))
        .describe("device-pool-bytes", "device-residency tier bytes (0 = off)", Some("268435456"))
        .describe("prefix-pool-bytes", "prefix-cache byte capacity (0 = off)", Some("67108864"))
        .describe("devices", "device shards to partition the runtime across", Some("1"))
        .describe("max-inflight-calls", "device calls in flight at once, per shard (1 = sync)", Some("1"))
        .describe("call-retries", "retry budget per failed device call", Some("4"))
        .describe("retry-backoff-ms", "base retry backoff, doubles per attempt", Some("5"))
        .describe("kv-quant", "KV precision: off | cold-q8 (int8 cold pages)", Some("cold-q8"))
        .describe("quantize-after-windows", "ladder windows a page stays f32 before demotion", Some("2"))
        .describe("trace-sample-every", "record every Nth flight-recorder event per kind (0 = off)", Some("1"))
        .describe("trace-buffer-events", "flight-recorder ring capacity in events", Some("65536"));
    if args.flag("help") {
        print!("{}", args.usage("lacache-serve"));
        return Ok(());
    }
    let cfg = ServeConfig::from_args(&args)?;
    let final_stats = run_server(cfg)?;
    println!("{final_stats}");
    Ok(())
}
