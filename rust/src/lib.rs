//! # lacache-serve
//!
//! Production-shaped reproduction of **LaCache: Ladder-Shaped KV Caching for
//! Efficient Long-Context Modeling of Large Language Models** (ICML 2025) as
//! a three-layer Rust + JAX + Pallas serving stack:
//!
//! - **Layer 3 (this crate)** — serving coordinator: request router,
//!   continuous batcher, prefill/decode scheduler and, centrally, the KV
//!   cache *policy* layer: LaCache's ladder retention + iterative compaction
//!   next to StreamingLLM / full-cache / H2O / TOVA / SnapKV / PyramidInfer
//!   baselines.
//! - **Layer 2 (python/compile, build-time only)** — a tiny Llama-style
//!   decoder in JAX whose prefill/score/decode programs are AOT-lowered to
//!   HLO text.
//! - **Layer 1 (python/compile/kernels)** — the Pallas flash-decode kernel
//!   over the compacted cache (attention-map-free: the property that gives
//!   LaCache its throughput edge over importance-based eviction).
//!
//! See PERF.md for the host<->device transfer layer (dirty-range incremental
//! KV gather, reusable scratch images) and the benchmark methodology, and
//! ROADMAP.md for the growth plan.

pub mod cache;
pub mod config;
pub mod data;
pub mod engine;
pub mod eval;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod util;

/// Locate the artifacts directory (env override, then repo-relative).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("LACACHE_ARTIFACTS") {
        return std::path::PathBuf::from(d);
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
