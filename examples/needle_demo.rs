//! Needle-in-a-haystack mini-heatmap: LaCache vs StreamingLLM at the same
//! budget (the Fig. 8 mechanism, terminal edition).
//!
//! ```bash
//! cargo run --release --example needle_demo -- --budget 128 --reps 2
//! ```

use anyhow::Result;
use lacache::eval::niah::niah_heatmap;
use lacache::runtime::Runtime;
use lacache::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let budget = args.usize_or("budget", 128);
    let reps = args.usize_or("reps", 2);
    let rt = Runtime::load(&lacache::artifacts_dir(), &["base"])?;
    let ctx = [384, 512, 768, 1024];
    let depths = [0.1, 0.3, 0.5, 0.7, 0.9];
    for (label, spec) in [
        ("StreamingLLM", format!("streaming:budget={budget}")),
        ("LaCache", format!("lacache_und:budget={budget},ratio=0.5")),
    ] {
        let h = niah_heatmap(&rt, "base", &spec, 128, 256, &ctx, &depths, reps, 123)?;
        println!("\n{label} @ budget {budget}: mean accuracy {:.1}%", h.mean() * 100.0);
        println!("{}", h.render());
    }
    println!("StreamingLLM evicts early/mid-context needles; LaCache's ladder keeps them in a subset of layers.");
    Ok(())
}
