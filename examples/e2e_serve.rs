//! End-to-end serving driver (the EXPERIMENTS.md headline run): starts the
//! full lacache-serve stack in-process, fires a batch of concurrent client
//! requests over TCP (retrieval prompts + freeform continuations), and
//! reports latency percentiles, throughput, and a needle accuracy spot-check.
//!
//! ```bash
//! cargo run --release --example e2e_serve -- --requests 24 --clients 4
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use lacache::config::ServeConfig;
use lacache::data::tasks::{fresh_entity, needle_prompt};
use lacache::server::run_server;
use lacache::util::args::Args;
use lacache::util::json::Json;
use lacache::util::rng::SplitMix64;
use lacache::util::stats::Samples;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize_or("requests", 24);
    let n_clients = args.usize_or("clients", 4);
    let listen = args.str_or("listen", "127.0.0.1:7411");
    let policy = args.str_or("policy", "lacache:budget=128,span=2");

    // server thread (owns the PJRT runtime)
    let cfg = ServeConfig { listen: listen.clone(), policy: policy.clone(), ..Default::default() };
    let server = std::thread::spawn(move || run_server(cfg));

    // wait for the listener
    let mut probe = None;
    for _ in 0..100 {
        if let Ok(s) = TcpStream::connect(&listen) {
            probe = Some(s);
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    drop(probe.context("server did not come up")?);
    println!("server up at {listen} with policy {policy}; firing {n_requests} requests from {n_clients} clients");

    // client threads: needle-retrieval prompts (scorable) over 512..1024-token contexts
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let listen = listen.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> Result<Vec<(f64, f64, f64)>> {
            let conn = TcpStream::connect(&listen)?;
            let mut reader = BufReader::new(conn.try_clone()?);
            let mut writer = conn;
            let mut out = Vec::new();
            for i in 0..per_client {
                let mut rng = SplitMix64::new((client * 1000 + i) as u64);
                let ctx = 512 + (i % 3) * 256;
                let e = fresh_entity(&mut rng);
                let task = needle_prompt(&mut rng, ctx, &[(0.4, e)], 0);
                let prompt: Vec<i64> = task.prompt.iter().map(|&t| t as i64).collect();
                let req = Json::from_pairs(vec![
                    ("op", "generate".into()),
                    ("id", ((client * 1000 + i) as i64).into()),
                    ("prompt_tokens", prompt.into()),
                    ("max_new_tokens", 4usize.into()),
                ]);
                writer.write_all(req.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let resp = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
                anyhow::ensure!(resp.bool_of("ok") == Some(true), "request failed: {line}");
                let gen: Vec<i32> = resp
                    .req("tokens")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|t| t.as_i64().unwrap() as i32)
                    .collect();
                let score = lacache::data::tasks::score_generation(&task, &gen);
                out.push((
                    resp.f64_of("ttft_ms").unwrap_or(0.0),
                    resp.f64_of("total_ms").unwrap_or(0.0),
                    score,
                ));
            }
            Ok(out)
        }));
    }
    let mut ttft = Samples::new();
    let mut total = Samples::new();
    let mut scores = Samples::new();
    for h in handles {
        for (tt, to, sc) in h.join().unwrap()? {
            ttft.record(tt);
            total.record(to);
            scores.record(sc);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // pull server-side stats, then shut down
    let conn = TcpStream::connect(&listen)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut writer = conn;
    writer.write_all(b"{\"op\":\"stats\",\"id\":9998}\n")?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let stats = Json::parse(&line).unwrap();
    writer.write_all(b"{\"op\":\"shutdown\",\"id\":9999}\n")?;
    writer.flush()?;
    let _ = server.join();

    println!("\n=== e2e serving report ===");
    println!("requests completed : {}", scores.len());
    println!("wall time          : {wall:.2}s  ({:.2} req/s)", scores.len() as f64 / wall);
    println!("ttft   (ms)        : {}", ttft.summary("ms"));
    println!("e2e    (ms)        : {}", total.summary("ms"));
    println!("needle accuracy    : {:.1}%", scores.mean() * 100.0);
    println!("server stats       : {}", stats.req("stats"));
    Ok(())
}
