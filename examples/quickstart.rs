//! Quickstart: load the AOT artifacts, run LaCache-compressed inference.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use lacache::cache::make_policy;
use lacache::data::corpus::Stream;
use lacache::data::tasks::{fresh_entity, needle_prompt};
use lacache::engine::{Engine, EngineOpts};
use lacache::runtime::Runtime;
use lacache::server::text::detokenize;
use lacache::util::rng::SplitMix64;

fn main() -> Result<()> {
    // 1. Load a model + its compiled programs (python never runs here).
    let rt = Runtime::load(&lacache::artifacts_dir(), &["base"])?;
    let cfg = rt.model("base")?.cfg.clone();
    println!("loaded `base`: {} layers, {} params", cfg.n_layers, rt.model("base")?.n_params);

    // 2. Build a LaCache engine: ladder retention with span S=L/4 under a
    //    128-slot per-layer budget.
    let policy = make_policy("lacache:budget=128,span=2", cfg.n_layers)?;
    println!("policy: {}", policy.name());
    let mut eng = Engine::new(
        &rt,
        EngineOpts {
            model: "base".into(),
            w: 128,
            c: 256,
            memory_budget_bytes: None,
            quantize_after_windows: None,
        },
        policy,
    )?;

    // 3. Teacher-forced perplexity on the synthetic corpus.
    let toks = Stream::default_eval(1).take_n(513);
    let lps = eng.feed_score(&toks[..512], &toks[1..513])?;
    let ppl = (-lps.iter().map(|&x| x as f64).sum::<f64>() / lps.len() as f64).exp();
    println!("512-token ppl under LaCache(128): {ppl:.2}");
    println!(
        "cache occupancy per layer: {:?} (budget 128, {} compactions)",
        eng.cache.lens, eng.n_compactions
    );

    // 4. Long-context retrieval: plant a needle at depth 0.3 of a 768-token
    //    context (3x the budget) and ask for it.
    let mut rng = SplitMix64::new(99);
    let e = fresh_entity(&mut rng);
    let task = needle_prompt(&mut rng, 768, &[(0.3, e.clone())], 0);
    eng.reset();
    eng.prefill(&task.prompt)?;
    let gen = eng.generate(4)?;
    println!("needle expected: {}", detokenize(&task.expected[0]));
    println!("model answered : {}", detokenize(&gen));
    Ok(())
}
