//! Continuous (infinite-length) generation demo — the paper's §3.3 claim.
//!
//! Streams tens of thousands of tokens through a fixed 128-slot budget with
//! LaCache's iterative compaction (memory stays constant), then shows the
//! full-cache run aborting with a simulated OOM.
//!
//! ```bash
//! cargo run --release --example infinite_stream -- --total 30000
//! ```

use anyhow::Result;
use lacache::engine::is_oom;
use lacache::eval::ppl::stream_ppl_curve;
use lacache::runtime::Runtime;
use lacache::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let total = args.usize_or("total", 30_000);
    let rt = Runtime::load(&lacache::artifacts_dir(), &["base"])?;

    println!("== LaCache(128), {total} tokens, constant memory ==");
    let curve =
        stream_ppl_curve(&rt, "base", "lacache:budget=128,span=2", 5, total, 2048, 128, 256, None)?;
    for (pos, ppl) in &curve {
        println!("  pos {pos:>7}  segment ppl {ppl:.2}");
    }

    println!("\n== full cache on the same stream (capacity 2048) ==");
    match stream_ppl_curve(&rt, "base", "full", 5, total, 512, 128, 2048, None) {
        Ok(curve) => {
            for (pos, ppl) in &curve {
                if ppl.is_nan() {
                    println!("  pos {pos:>7}  ** OOM — generation stops here **");
                } else {
                    println!("  pos {pos:>7}  segment ppl {ppl:.2}");
                }
            }
        }
        Err(e) if is_oom(&e) => println!("  OOM: {e}"),
        Err(e) => return Err(e),
    }
    println!("\nLaCache streamed {total} tokens in O(1) memory; full cache did not.");
    Ok(())
}
